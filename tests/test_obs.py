"""Observability layer (repro.obs): tracing, metrics registry, auditor.

The hard contracts under test:

* tracing OFF (the default) carries NO trace object anywhere — today's
  path, byte for byte;
* tracing ON changes no answer: bit-identical to the untraced equal-seed
  session across solo / herd / batched / cached / staged / sharded runs
  (spans only observe — perf_counter + attr dicts);
* every COMPLETED, FALLBACK, or FAILED query yields a CLOSED span tree
  (open_spans() == []), including mid-group captured failures, and the
  ErrorFrame path still terminates a blocked stream();
* the metrics registry absorbs the scattered counters (collectors match
  their sources) and renders Prometheus text; collectors die with their
  owners;
* audit mode perturbs nothing (bit-identical answers, untouched cache
  keys) while recording observed <= promised error for honest runs.
"""

import json

import numpy as np
import pytest

from repro.api import ErrorFrame, FinalFrame, PilotFrame, Session, \
    SessionConfig
from repro.core.taqa import PilotDB
from repro.engine.datagen import tpch_catalog
from repro.obs import GLOBAL, GuaranteeAuditor, MetricsRegistry, QueryTrace
from repro.obs import trace as trace_mod
from repro.obs.audit import provenance_of
from repro.serve.sql_gateway import SqlGateway

HERD_SQL = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            "WHERE l_quantity < 24 ERROR 8% CONFIDENCE 95%")
GROUPED_SQL = ("SELECT SUM(l_quantity) AS q, COUNT(*) AS n FROM lineitem "
               "WHERE l_quantity < 30 GROUP BY l_returnflag MAXGROUPS 3 "
               "ERROR 10% CONFIDENCE 90%")

SERIAL_CFG = SessionConfig(async_workers=0, share_pilots=False,
                           result_cache_size=0)
NOCACHE_CFG = SessionConfig(async_workers=4, result_cache_size=0)
TRACE_SERIAL = SessionConfig(async_workers=0, share_pilots=False,
                             result_cache_size=0, tracing=True)
TRACE_HERD = SessionConfig(async_workers=4, result_cache_size=0,
                           tracing=True)


@pytest.fixture(scope="module")
def catalog():
    return tpch_catalog(scale_rows=200_000, block_rows=32, seed=0)


def _assert_bitwise(a, b):
    assert np.array_equal(a.values, b.values)
    assert np.array_equal(a.group_present, b.group_present)
    assert list(a.names) == list(b.names)


# ---------------------------------------------------------------------------
# Zero-overhead default: tracing OFF is today's path
# ---------------------------------------------------------------------------

def test_tracing_off_by_default(catalog):
    s = Session(catalog, seed=3, config=SERIAL_CFG)
    h = s.sql(HERD_SQL)
    assert h._trace is None
    assert h.trace() is None and h.trace("chrome") is None
    assert trace_mod.active() is None
    # instrumentation points degrade to the shared no-op span
    assert trace_mod.span("anything") is trace_mod.NULL_SPAN


def test_trace_format_validated(catalog):
    s = Session(catalog, seed=3, config=TRACE_SERIAL)
    h = s.sql(HERD_SQL)
    with pytest.raises(ValueError):
        h.trace(fmt="protobuf")


# ---------------------------------------------------------------------------
# Bit-identity: tracing observes, never steers
# ---------------------------------------------------------------------------

def test_traced_solo_bitwise_identical(catalog):
    plain = Session(catalog, seed=3, config=SERIAL_CFG).sql(HERD_SQL)
    traced = Session(catalog, seed=3, config=TRACE_SERIAL).sql(HERD_SQL)
    assert traced.fallback is None
    _assert_bitwise(traced.answer, plain.answer)


def test_traced_herd_bitwise_identical(catalog):
    solo = Session(catalog, seed=11, config=SERIAL_CFG).sql(HERD_SQL)
    rt = Session(catalog, seed=11, config=TRACE_HERD)
    handles = [rt.submit(HERD_SQL) for _ in range(5)]
    p0 = rt.executor.pilots_run
    rt.drain()
    assert rt.executor.pilots_run - p0 == 1  # tracing kept pilot sharing
    for h in handles:
        _assert_bitwise(h.answer, solo.answer)
        assert h._trace is not None and h._trace.open_spans() == []
    rt.close()


def test_traced_batched_finals_bitwise(catalog):
    template = ("SELECT SUM(l_extendedprice) AS rev FROM lineitem "
                "WHERE l_quantity < {} ERROR 10% CONFIDENCE 90%")
    cuts = [18, 24, 30, 36]
    serial = Session(catalog, seed=9, config=SERIAL_CFG)
    want = {c: serial.sql(template.format(c)).answer for c in cuts}
    rt = Session(catalog, seed=9, config=TRACE_HERD)
    handles = {c: rt.submit(template.format(c)) for c in cuts}
    rt.drain()
    for c, h in handles.items():
        _assert_bitwise(h.answer, want[c])
        assert h._trace.open_spans() == []
    rt.close()


def test_traced_cached_reissue_bitwise_and_provenance(catalog):
    s = Session(catalog, seed=13, config=SessionConfig(tracing=True))
    first = s.sql(HERD_SQL)
    again = s.sql(HERD_SQL)
    assert again.cached
    _assert_bitwise(again.answer, first.answer)
    assert again._trace.open_spans() == []
    hits = [sp for sp in again._trace.find("cache_lookup")
            if sp.attrs.get("hit")]
    assert hits  # the trace recorded the cache serve
    assert provenance_of(again) == "cached"
    s.close()


@pytest.mark.parametrize("shards", [1, 2])
def test_traced_sharded_bitwise_with_fanout_span(catalog, shards):
    mono = Session(catalog, seed=31, config=SERIAL_CFG).sql(GROUPED_SQL)
    s = Session(seed=31, config=TRACE_SERIAL)
    for name, tab in catalog.items():
        s.register_table(name, tab,
                         shards=shards if name == "lineitem" else None)
    h = s.sql(GROUPED_SQL)
    _assert_bitwise(h.answer, mono.answer)
    fanouts = h._trace.find("shard_fanout")
    if mono.fallback is None:
        assert fanouts and fanouts[0].attrs["shards"] == shards
        assert "+dist" in provenance_of(h)


def test_traced_staged_bitwise_with_staged_tags(catalog):
    def _run(rates, cfg):
        s = Session(seed=41, config=cfg)
        for name, tab in catalog.items():
            s.register_table(name, tab,
                             staged_rates=rates if name == "lineitem"
                             else None)
        return s, s.sql(HERD_SQL)

    _, ref = _run([1e-9], SERIAL_CFG)      # ladder that never serves
    s, hot = _run(True, TRACE_SERIAL)      # default ladder, traced
    assert s.executor.staged_info()["hits"] > 0
    _assert_bitwise(hot.answer, ref.answer)
    tagged = [sp for sp in hot._trace.find("scan")
              if sp.attrs.get("staged")]
    assert tagged  # staged-rung serves are visible in the trace
    assert "+staged" in provenance_of(hot)


# ---------------------------------------------------------------------------
# Span tree: vocabulary, closure, export
# ---------------------------------------------------------------------------

def test_solo_span_vocabulary_and_attrs(catalog):
    s = Session(catalog, seed=3, config=TRACE_SERIAL)
    h = s.sql(HERD_SQL)
    tr = h._trace
    assert tr.status == "ok" and tr.open_spans() == []
    names = set(tr.span_names())
    assert {"query", "parse", "lower", "pilot", "rate_solve",
            "final", "deliver"} <= names
    pilot, = tr.find("pilot")
    assert pilot.attrs["table"] == "lineitem"
    assert pilot.attrs["scanned_bytes"] > 0
    assert pilot.attrs["shared"] is False
    final, = tr.find("final")
    assert final.attrs["scanned_bytes"] > 0
    lower, = tr.find("lower")
    assert lower.attrs["seed"] == h.seed
    # nested engine scans attach under their stages
    assert any(c.name == "scan" for c in pilot.children)


def test_scheduled_drain_closes_schedule_span(catalog):
    s = Session(catalog, seed=3, config=TRACE_HERD)
    h = s.submit(HERD_SQL)
    assert "schedule" in h._trace.open_spans()
    s.drain()
    assert h._trace.open_spans() == []
    sched, = h._trace.find("schedule")
    assert sched.t1 is not None
    s.close()


def test_trace_exports_json_and_chrome(catalog):
    s = Session(catalog, seed=3, config=TRACE_SERIAL)
    h = s.sql(HERD_SQL)
    tree = h.trace()
    json.dumps(tree)  # JSON-able throughout
    assert tree["status"] == "ok" and tree["root"]["name"] == "query"
    assert tree["root"]["attrs"]["sql"] == HERD_SQL
    events = h.trace("chrome")
    json.dumps(events)
    assert all(e["ph"] == "X" and e["pid"] == h.query_id for e in events)
    assert {e["name"] for e in events} >= {"query", "pilot", "final"}
    # durations in microseconds, start times relative to the trace
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in events)


def test_failed_query_trace_closed_with_error_status(catalog):
    s = Session(catalog, seed=3, config=TRACE_HERD)
    h = s.submit("SELECT COUNT(*) AS n FROM not_a_table GROUP BY g")
    s.drain()
    assert h.status == "failed"
    assert h._trace.status == "error" and h._trace.open_spans() == []
    assert h.trace()["root"]["attrs"]["error"] == h.error
    s.close()


def test_mid_group_failure_traced_closes_spans_and_error_frame(
        catalog, monkeypatch):
    """Satellite: a mid-group failure under tracing must close the failed
    member's span tree AND emit its terminal ErrorFrame — stream() ends."""
    base = ("SELECT SUM(l_extendedprice) AS rev FROM lineitem "
            "WHERE l_shipdate < 2000 ")
    sqls = [base + f"ERROR {e}% CONFIDENCE 95%" for e in (8, 7, 6)]
    session = Session(catalog, seed=5, config=TRACE_HERD)
    real = PilotDB.prepare_final

    def flaky(self, q, spec, outcome, seed, shared=False):
        if abs(spec.error - 0.07) < 1e-12:
            raise RuntimeError("worker exploded mid-group")
        return real(self, q, spec, outcome, seed, shared=shared)

    monkeypatch.setattr(PilotDB, "prepare_final", flaky)
    handles = [session.submit(s, stream=True) for s in sqls]
    session.drain()
    assert [h.status for h in handles] == ["done", "failed", "done"]
    for h in handles:
        assert h._trace.open_spans() == []  # every tree closed
        frames = list(h.stream())           # terminates, never hangs
        assert frames[-1].terminal
    failed = handles[1]
    assert failed._trace.status == "error"
    assert isinstance(failed.frames()[-1], ErrorFrame)
    # siblings still completed with full span trees and pilot sharing
    assert {"pilot", "final"} <= set(handles[0]._trace.span_names())
    session.close()


def test_trace_mechanics_null_span_after_finish():
    tr = QueryTrace(0)
    with tr.span("a", k=1) as sp:
        assert tr.open_spans() == ["query", "a"]
        sp.set(extra=2)
    assert tr.open_spans() == ["query"]
    tr.finish("ok")
    assert tr.finished and tr.open_spans() == []
    # post-finish instrumentation degrades to no-ops
    assert tr.span("late") is trace_mod.NULL_SPAN
    before = tr.span_names()
    tr.record("late2")
    tr.finish("error")  # idempotent: first status wins
    assert tr.span_names() == before and tr.status == "ok"


def test_trace_span_error_status_on_exception():
    tr = QueryTrace(1)
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("bad")
    sp, = tr.find("boom")
    assert sp.status == "error" and "RuntimeError: bad" in sp.attrs["error"]
    assert not sp.open


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_registry_instruments_get_or_create_and_kinds():
    reg = MetricsRegistry()
    c = reg.counter("x_total", "help text")
    c.inc()
    c.inc(2)
    assert reg.counter("x_total").value == 3
    g = reg.gauge("x_now")
    g.set(1.5)
    assert g.value == 1.5
    hist = reg.histogram("x_seconds")
    hist.observe(0.003)
    hist.observe(0.3)
    assert hist.count == 2 and hist.max == 0.3
    with pytest.raises(TypeError):
        reg.gauge("x_total")  # kind mismatch is a bug, not a new metric


def test_registry_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests").inc(4)
    reg.histogram("lat_seconds", buckets=(0.1, 1.0)).observe(0.5)
    reg.register_collector("cache", lambda: {"hits": 2, "nested": {"n": 1},
                                             "name": "dropme"})
    text = reg.to_text()
    assert "# TYPE req_total counter" in text
    assert "req_total 4" in text
    assert '# HELP req_total requests' in text
    assert 'lat_seconds_bucket{le="0.1"} 0' in text
    assert 'lat_seconds_bucket{le="1"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 1' in text
    assert "lat_seconds_count 1" in text
    # collector snapshots flatten to path-joined gauges; strings dropped
    assert "cache_hits 2" in text and "cache_nested_n 1" in text
    assert "dropme" not in text
    assert text.endswith("\n")


def test_registry_collector_dies_with_owner():
    reg = MetricsRegistry()

    class Owner:
        pass

    o = Owner()
    reg.register_collector("mine", lambda: {"v": 1}, owner=o)
    assert reg.tree() == {"mine": {"v": 1}}
    del o
    assert reg.tree() == {}  # pruned at read, never a dead scrape


def test_session_collectors_match_sources(catalog):
    s = Session(catalog, seed=5)
    s.sql(HERD_SQL)
    tree = s.metrics.tree()
    info = s.compile_cache_info()
    assert tree["compile_cache"]["hits"] == info.hits
    assert tree["compile_cache"]["misses"] == info.misses
    rc = s.result_cache_info()
    assert tree["result_cache"]["hits"] == rc.hits
    assert tree["result_cache"]["bytes_used"] == rc.bytes_used
    assert tree["staged"]["tables"] == {}
    assert tree["runtime"]["queries_run"] == s.executor.queries_run
    assert tree["runtime"]["pilots_run"] == s.executor.pilots_run
    assert tree["audit"] == {"runs": 0, "violations": 0, "errors": 0,
                             "max_error_ratio": 0.0}
    s.close()


def test_drain_counters_land_in_registry(catalog):
    s = Session(catalog, seed=5, config=NOCACHE_CFG)
    s.submit(HERD_SQL)
    s.submit(HERD_SQL)
    s.drain()
    assert s.metrics.counter("pilotdb_drains_total").value == 1
    assert s.metrics.counter("pilotdb_drained_queries_total").value == 2
    assert s.metrics.histogram("pilotdb_drain_wall_seconds").count == 1
    s.close()


def test_gateway_metrics_text_includes_gateway_counters(catalog):
    s = Session(catalog, seed=5)
    gw = SqlGateway(s)
    gw.submit("c0", HERD_SQL)
    gw.run()
    text = gw.metrics_text()
    assert f"{gw._collector_name}_requests 1" in text
    assert "compile_cache_hits" in text
    assert "result_cache_bytes_used" in text
    s.close()


# ---------------------------------------------------------------------------
# Guarantee auditor
# ---------------------------------------------------------------------------

def test_audit_mode_bit_identical_and_honest(catalog):
    plain = Session(catalog, seed=7, config=SERIAL_CFG).sql(HERD_SQL)
    audit_cfg = SessionConfig(async_workers=0, share_pilots=False,
                              result_cache_size=0, tracing=True, audit=True)
    s = Session(catalog, seed=7, config=audit_cfg)
    h = s.sql(HERD_SQL)
    # non-perturbation: the audited answer is the unaudited one, bitwise
    _assert_bitwise(h.answer, plain.answer)
    rec = h.audit_record
    assert rec is not None and rec.skipped is None
    assert rec.passed and rec.observed_error <= rec.promised_error
    assert 0.0 <= rec.error_ratio <= 1.0
    assert rec.provenance == "fresh"
    summ = s.auditor.summary()
    assert summ["runs"] == 1 and summ["violations"] == 0
    assert summ["max_error_ratio"] == rec.error_ratio
    # the ratio landed in the registry histogram + gauge
    assert s.metrics.histogram("pilotdb_audit_error_ratio").count == 1
    assert s.metrics.gauge(
        "pilotdb_audit_max_error_ratio").value == rec.error_ratio


def test_audit_skips_exact_answers_without_second_scan(catalog):
    s = Session(catalog, seed=7, config=SessionConfig(audit=True))
    h = s.sql("SELECT COUNT(*) AS n FROM lineitem")  # no spec: exact
    rec = h.audit_record
    assert rec.skipped == "answer is exact"
    assert rec.observed_error == 0.0 and rec.passed
    assert rec.exact_wall_s == 0.0  # no extra scan was paid
    assert s.auditor.summary()["skipped_exact"] == 1
    s.close()


def test_audit_grouped_checks_every_covered_group(catalog):
    cfg = SessionConfig(async_workers=0, share_pilots=False,
                        result_cache_size=0, audit=True)
    s = Session(catalog, seed=21, config=cfg)
    h = s.sql(GROUPED_SQL)
    rec = h.audit_record
    if h.fallback is None:
        assert rec.skipped is None
        assert rec.groups_checked >= 1
        assert rec.passed


def test_audit_never_raises_into_query_path(catalog, monkeypatch):
    cfg = SessionConfig(async_workers=0, share_pilots=False,
                        result_cache_size=0, audit=True)
    s = Session(catalog, seed=7, config=cfg)

    def broken_exact(self, q):
        raise RuntimeError("audit scan died")

    monkeypatch.setattr(PilotDB, "exact", broken_exact)
    h = s.sql(HERD_SQL)
    assert h.status == "done"  # the client still got its answer
    assert h.audit_record is None
    assert s.auditor.summary()["errors"] == 1
    assert s.metrics.counter("pilotdb_audit_errors_total").value == 1


def test_explain_reports_guarantee_and_audit(catalog):
    cfg = SessionConfig(async_workers=0, share_pilots=False,
                        result_cache_size=0, tracing=True, audit=True)
    s = Session(catalog, seed=7, config=cfg)
    h = s.sql(HERD_SQL)
    text = h.explain()
    assert f"Query {h.query_id}:" in text
    assert "ERROR 8% CONFIDENCE 95%" in text
    assert "provenance: fresh" in text
    assert "pilot: table=lineitem" in text
    assert "solved rates" in text
    assert "audit: observed=" in text and "[OK]" in text


def test_explain_failed_handle(catalog):
    s = Session(catalog, seed=3)
    h = s.failed_handle("SELEKT 1", "SqlSyntaxError: nope")
    text = h.explain()
    assert "FAILED" in text and "SqlSyntaxError" in text
    s.close()


def test_global_registry_exists():
    # the process-wide registry is importable and scrapes cleanly even
    # when empty
    assert isinstance(GLOBAL.to_text(), str)


# ---------------------------------------------------------------------------
# Prometheus exposition: HELP escaping + duplicate-name guard (satellite)
# ---------------------------------------------------------------------------

def test_prometheus_help_escaping_and_duplicate_guard():
    reg = MetricsRegistry()
    reg.counter("dup_hits", "line one\nline two with \\ backslash").inc(3)
    # a collector whose flattened path collides with the instrument name
    reg.register_collector("dup", lambda: {"hits": 99, "fresh": 7})
    # a collector colliding with a histogram's synthesized child series
    reg.histogram("lat_seconds", buckets=(0.1,)).observe(0.05)
    reg.register_collector("lat", lambda: {"seconds_count": 42})
    text = reg.to_text()
    # exposition stays valid: comments, or exactly "name value" lines
    for line in text.splitlines():
        assert line.startswith("#") or len(line.split()) == 2, line
    # HELP newline/backslash escaped into one comment line
    assert ("# HELP dup_hits line one\\nline two with \\\\ backslash"
            in text.splitlines())
    # the instrument wins the collision; the collector gauge is skipped
    dup_lines = [ln for ln in text.splitlines()
                 if ln.split()[0] == "dup_hits"]
    assert dup_lines == ["dup_hits 3"]
    # non-colliding collector keys still flatten
    assert "dup_fresh 7" in text
    # the histogram's _count child also guards against collector collisions
    count_lines = [ln for ln in text.splitlines()
                   if ln.split()[0] == "lat_seconds_count"]
    assert count_lines == ["lat_seconds_count 1"]


# ---------------------------------------------------------------------------
# Continuous telemetry: time-series, SLO, flight recorder, sampled tracing
# ---------------------------------------------------------------------------

import dataclasses as _dc

from repro.obs.events import FlightRecorder, replay, rebuild_timeseries
from repro.obs.slo import SloTarget
from repro.obs.timeseries import Ring, TemplateTimeSeries, quantile

TEMPLATE_SQL = ("SELECT SUM(l_extendedprice) AS rev FROM lineitem "
                "WHERE l_quantity < {} ERROR 10% CONFIDENCE 90%")


def _telemetry_cfg(tmp_path=None, **kw):
    base = dict(async_workers=4, result_cache_size=0, telemetry=True)
    if tmp_path is not None:
        base["flight_recorder"] = str(tmp_path / "events.jsonl")
    base.update(kw)
    return SessionConfig(**base)


def test_ring_and_quantile_mechanics():
    r = Ring(4)
    assert r.stats()["window"] == 0 and r.last() == 0.0
    for v in [5.0, 1.0, 3.0]:
        r.push(v)
    assert r.values() == [5.0, 1.0, 3.0] and r.last() == 3.0
    for v in [7.0, 9.0]:
        r.push(v)  # wraps: 5.0 evicted
    assert r.values() == [1.0, 3.0, 7.0, 9.0]
    assert r.last() == 9.0 and r.total == 5
    st = r.stats()
    assert st["p50"] == 3.0 and st["p99"] == 9.0 and st["max"] == 9.0
    assert quantile([], 0.5) == 0.0
    assert quantile([2.0, 1.0], 0.5) == 1.0
    with pytest.raises(ValueError):
        Ring(0)


def test_timeseries_store_eviction_and_slo_stats():
    ts = TemplateTimeSeries(window=8, max_templates=2)
    ts.record_delivery("a", latency_s=0.1, fallback=True)
    ts.record_delivery("b", latency_s=0.2)
    ts.record_delivery("a", latency_s=0.3)
    ts.record_delivery("c", latency_s=0.4)  # evicts b (LRU)
    assert set(ts.keys()) == {"a", "c"}
    st = ts.slo_stats("a")
    assert st["samples"] == 2 and st["fallback_rate"] == 0.5
    ts.record_audit("a", 0.7, passed=False)
    assert ts.slo_stats("a")["violation_rate"] == 1.0
    ts.record_drain(0.01, 0.05)
    ts.record_drain(None, None)
    snap = ts.snapshot()
    assert snap["drains"] == 2 and snap["ttff_s"]["window"] == 1
    json.dumps(snap)


def test_telemetry_off_by_default_and_bit_identical_on(catalog, tmp_path):
    plain = Session(catalog, seed=17, config=NOCACHE_CFG)
    assert plain.timeseries is None and plain.slo is None
    assert plain.recorder is None
    ph = [plain.submit(TEMPLATE_SQL.format(c)) for c in (18, 24, 30)]
    plain.drain()

    cfg = _telemetry_cfg(tmp_path, trace_sample=1.0,
                         slo_targets=(SloTarget(p95_latency_s=3600.0),))
    tele = Session(catalog, seed=17, config=cfg)
    th = [tele.submit(TEMPLATE_SQL.format(c)) for c in (18, 24, 30)]
    tele.drain()
    # full telemetry (time-series + SLO + recorder + sampled tracing)
    # changes no answer: bit-identical to the equal-seed plain session
    for a, b in zip(ph, th):
        _assert_bitwise(a.answer, b.answer)
    assert len(tele.timeseries.keys()) == 1  # one constant-varied template
    key = tele.timeseries.keys()[0]
    assert key == tele.template_key(TEMPLATE_SQL.format(18))
    s = tele.timeseries.series(key)
    assert s.deliveries == 3 and len(s.latency_s) == 3
    assert s.failures == 0
    tele.close()
    plain.close()


def test_timeseries_rides_registry_and_stats_payload(catalog, tmp_path):
    cfg = _telemetry_cfg(tmp_path)
    s = Session(catalog, seed=5, config=cfg)
    gw = SqlGateway(s)
    gw.submit("c0", HERD_SQL)
    gw.submit("c1", HERD_SQL)
    gw.run()
    tree = s.metrics.tree()
    assert tree["timeseries"]["enabled"] is True
    payload = gw.stats_payload()
    ts_section = payload["timeseries"]
    assert ts_section["enabled"] is True and ts_section["drains"] >= 1
    key = s.template_key(HERD_SQL)
    tmpl = ts_section["templates"][key]
    assert tmpl["deliveries"] == 2
    assert tmpl["latency_s"]["window"] == 2
    assert tmpl["latency_s"]["p95"] > 0
    assert tmpl["sql"] == HERD_SQL
    json.dumps(payload)
    # the quantiles flow through Prometheus exposition too
    text = gw.metrics_text()
    for line in text.splitlines():
        assert line.startswith("#") or len(line.split()) == 2, line
    assert "timeseries_enabled 1" in text
    assert f"timeseries_templates_{key}_deliveries 2" in text
    s.close()


def test_slo_breach_round_trip(catalog, tmp_path):
    """Injected impossible target -> breach counter + flight-recorder event
    + slo_report() entry (the acceptance round-trip)."""
    cfg = _telemetry_cfg(
        tmp_path, slo_targets=(SloTarget(p95_latency_s=1e-9),
                               SloTarget(max_fallback_rate=0.99)))
    s = Session(catalog, seed=5, config=cfg)
    gw = SqlGateway(s)
    gw.submit("c0", HERD_SQL)
    gw.run()
    assert s.metrics.counter("pilotdb_slo_breaches_total").value >= 1
    assert s.metrics.counter("pilotdb_slo_evaluations_total").value >= 2
    rows = gw.slo_report()
    breached = [r for r in rows if r["breached"]]
    assert breached and breached[0]["metric"] == "p95_latency_s"
    assert breached[0]["observed"] > breached[0]["target"]
    assert breached[0]["breaches_total"] >= 1
    # the generous fallback-rate target did NOT breach
    ok = [r for r in rows if r["metric"] == "max_fallback_rate"]
    assert ok and not ok[0]["breached"]
    summary = s.slo.summary()
    assert summary["enabled"] and summary["recent_breaches"]
    s.close()
    events = list(replay(str(tmp_path / "events.jsonl")))
    assert any(e["ev"] == "slo_breach"
               and e["metric"] == "p95_latency_s" for e in events)


def test_slo_targets_require_telemetry(catalog):
    with pytest.raises(ValueError, match="telemetry"):
        Session(catalog, seed=5, config=SessionConfig(
            slo_targets=(SloTarget(p95_latency_s=1.0),)))


def test_slo_per_template_rule_matches_only_its_template(catalog, tmp_path):
    cfg = _telemetry_cfg(tmp_path)
    s = Session(catalog, seed=5, config=cfg)
    other = "SELECT COUNT(*) AS n FROM lineitem"
    key = s.template_key(HERD_SQL)
    s.slo.set_target(template=key, p95_latency_s=1e-9)
    s.submit(HERD_SQL)
    s.submit(other)
    s.drain()
    rows = s.slo.report()
    assert all(r["template"] == key for r in rows)
    assert any(r["breached"] for r in rows)
    s.close()


def test_flight_recorder_event_schema_and_replay(catalog, tmp_path):
    path = tmp_path / "events.jsonl"
    cfg = _telemetry_cfg(tmp_path)
    s = Session(catalog, seed=5, config=cfg)
    s.submit(HERD_SQL)
    s.submit("SELECT COUNT(*) AS n FROM lineitem")  # exact: no pilot
    s.drain()
    s.close()
    events = list(replay(str(path)))
    kinds = [e["ev"] for e in events]
    assert kinds.count("submit") == 2
    assert kinds.count("deliver") == 2
    assert "pilot" in kinds and "rate_solve" in kinds and "final" in kinds
    # seq is monotone, every record stamped
    seqs = [e["seq"] for e in events]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert all(e["t"] > 0 for e in events)
    deliver = [e for e in events if e["ev"] == "deliver"
               and e["template"] == s.template_key(HERD_SQL)]
    assert deliver
    d = deliver[0]
    assert d["latency_s"] > 0 and d["scanned_bytes"] > 0
    assert d["fallback"] is False and d["cached"] is False
    # offline rebuild reproduces the live store's per-template counters
    live = s.timeseries
    rebuilt = rebuild_timeseries(replay(str(path)))
    assert set(rebuilt.keys()) == set(live.keys())
    for key in live.keys():
        a, b = live.series(key), rebuilt.series(key)
        assert (a.deliveries, a.cached, a.shared, a.fused, a.fallbacks,
                a.failures) == (b.deliveries, b.cached, b.shared, b.fused,
                                b.fallbacks, b.failures)
        assert b.latency_s.values() == pytest.approx(
            a.latency_s.values(), abs=1e-6)


def test_flight_recorder_unwritable_target_never_raises(catalog):
    cfg = SessionConfig(
        async_workers=0, share_pilots=False, result_cache_size=0,
        flight_recorder="/nonexistent-dir-for-pilotdb-tests/events.jsonl")
    plain = Session(catalog, seed=7, config=SERIAL_CFG).sql(HERD_SQL)
    s = Session(catalog, seed=7, config=cfg)
    h = s.sql(HERD_SQL)  # the recorder drops, the query answers
    assert h.status == "done"
    _assert_bitwise(h.answer, plain.answer)
    assert s.recorder.stats()["dropped"] > 0
    assert s.recorder.stats()["emitted"] == 0
    s.close()  # close() with a never-opened file is a no-op


def test_flight_recorder_rotation_mid_drain(catalog, tmp_path):
    path = tmp_path / "tiny.jsonl"
    cfg = _telemetry_cfg(None, flight_recorder=str(path),
                         flight_recorder_max_bytes=1024,  # floor
                         flight_recorder_max_files=2)
    plain = Session(catalog, seed=13, config=NOCACHE_CFG)
    ph = [plain.submit(TEMPLATE_SQL.format(c)) for c in (18, 24, 30, 36)]
    plain.drain()
    s = Session(catalog, seed=13, config=cfg)
    th = [s.submit(TEMPLATE_SQL.format(c)) for c in (18, 24, 30, 36)]
    s.drain()
    for a, b in zip(ph, th):
        _assert_bitwise(a.answer, b.answer)
    stats = s.recorder.stats()
    assert stats["rotations"] >= 1 and stats["dropped"] == 0
    s.close()
    # the log's footprint is bounded; surviving records still replay and
    # the LIVE file's terminal events are intact
    assert path.exists() and (tmp_path / "tiny.jsonl.1").exists()
    events = list(replay(str(path)))
    assert events and all("ev" in e for e in events)
    plain.close()


def test_flight_recorder_mid_group_failure_logs_terminal_event(
        catalog, tmp_path, monkeypatch):
    """A mid-group member failure still logs its fail event; siblings'
    answers and deliver events are unaffected, nothing raises."""
    path = tmp_path / "events.jsonl"
    base = ("SELECT SUM(l_extendedprice) AS rev FROM lineitem "
            "WHERE l_shipdate < 2000 ")
    sqls = [base + f"ERROR {e}% CONFIDENCE 95%" for e in (8, 7, 6)]
    cfg = _telemetry_cfg(tmp_path)
    s = Session(catalog, seed=5, config=cfg)
    real = PilotDB.prepare_final

    def flaky(self, q, spec, outcome, seed, shared=False):
        if abs(spec.error - 0.07) < 1e-12:
            raise RuntimeError("worker exploded mid-group")
        return real(self, q, spec, outcome, seed, shared=shared)

    monkeypatch.setattr(PilotDB, "prepare_final", flaky)
    handles = [s.submit(x) for x in sqls]
    s.drain()
    assert [h.status for h in handles] == ["done", "failed", "done"]
    key = s.template_key(sqls[0])
    series = s.timeseries.series(key)
    assert series.deliveries == 3 and series.failures == 1
    s.close()
    events = list(replay(str(path)))
    fails = [e for e in events if e["ev"] == "fail"]
    assert len(fails) == 1
    assert fails[0]["qid"] == handles[1].query_id
    assert "worker exploded" in fails[0]["error"]
    assert sum(1 for e in events if e["ev"] == "deliver") == 2


def test_trace_sampling_deterministic_and_content_derived(catalog):
    cuts = list(range(10, 40, 3))
    cfg = SessionConfig(async_workers=0, share_pilots=False,
                        result_cache_size=0, trace_sample=0.5)

    def sampled_set(seed):
        s = Session(catalog, seed=seed, config=cfg)
        out = {}
        for c in cuts:
            h = s.sql(TEMPLATE_SQL.format(c))
            out[c] = h._trace_sampled
            # sampling implies a trace (tracing flag is off); unsampled
            # queries carry none — today's path byte for byte
            assert (h._trace is not None) == h._trace_sampled
        s.close()
        return out

    first = sampled_set(23)
    again = sampled_set(23)
    assert first == again  # equal seeds sample the IDENTICAL query set
    assert any(first.values()) and not all(first.values())  # p=0.5 mixes
    other = sampled_set(24)
    assert other != first  # the decision hashes the session seed too


def test_trace_sample_bounds_and_edges(catalog):
    with pytest.raises(ValueError, match="trace_sample"):
        Session(catalog, seed=3, config=SessionConfig(trace_sample=1.5))
    s0 = Session(catalog, seed=3, config=SessionConfig(
        async_workers=0, share_pilots=False, result_cache_size=0,
        trace_sample=0.0))
    assert s0.sql(HERD_SQL)._trace is None
    s1 = Session(catalog, seed=3, config=SessionConfig(
        async_workers=0, share_pilots=False, result_cache_size=0,
        trace_sample=1.0))
    h = s1.sql(HERD_SQL)
    assert h._trace_sampled and h._trace is not None
    # the sampled span tree landed in the session's recent-traces ring
    assert len(s1.recent_traces) == 1
    assert s1.recent_traces[0]["query_id"] == h.query_id
    s0.close()
    s1.close()


def test_sampled_traces_land_in_flight_recorder(catalog, tmp_path):
    path = tmp_path / "events.jsonl"
    cfg = SessionConfig(async_workers=0, share_pilots=False,
                        result_cache_size=0, trace_sample=1.0,
                        flight_recorder=str(path))
    s = Session(catalog, seed=3, config=cfg)
    h = s.sql(HERD_SQL)
    s.close()
    events = list(replay(str(path)))
    traces = [e for e in events if e["ev"] == "trace"]
    assert len(traces) == 1
    tree = traces[0]["trace"]
    assert tree["query_id"] == h.query_id
    assert tree["root"]["name"] == "query"
    subs = [e for e in events if e["ev"] == "submit"]
    assert subs and subs[0]["sampled"] is True


def test_audit_feeds_timeseries_and_recorder(catalog, tmp_path):
    path = tmp_path / "events.jsonl"
    cfg = SessionConfig(async_workers=0, share_pilots=False,
                        result_cache_size=0, telemetry=True, audit=True,
                        flight_recorder=str(path))
    s = Session(catalog, seed=7, config=cfg)
    h = s.sql(HERD_SQL)
    rec = h.audit_record
    assert rec is not None and rec.skipped is None
    key = s.template_key(HERD_SQL)
    series = s.timeseries.series(key)
    assert series.audited == 1
    assert series.error_ratio.last() == pytest.approx(rec.error_ratio)
    assert series.audit_violations == (0 if rec.passed else 1)
    s.close()
    audits = [e for e in list(replay(str(path))) if e["ev"] == "audit"]
    assert len(audits) == 1
    assert audits[0]["passed"] == rec.passed
    assert audits[0]["ratio"] == pytest.approx(rec.error_ratio, abs=1e-6)


def test_fused_provenance_in_explain_and_timeseries(catalog):
    """Satellite: audit-mode + fused_taqa interplay — explain() reports the
    fused span, provenance gains +fused, the time-series counts the fused
    delivery, and the audit still passes on the fused answer."""
    cfg = SessionConfig(async_workers=0, result_cache_size=0,
                        telemetry=True, audit=True, tracing=True,
                        fused_taqa=True)
    s = Session(catalog, seed=7, config=cfg)
    h = s.submit(HERD_SQL)
    s.drain()
    assert h.status == "done"
    fused_spans = h._trace.find("fused")
    text = h.explain()
    if fused_spans and fused_spans[0].attrs.get("engaged"):
        assert "+fused" in provenance_of(h)
        assert "fused: engaged" in text
        key = s.template_key(HERD_SQL)
        assert s.timeseries.series(key).fused == 1
    elif fused_spans:
        assert "fused: attempted" in text
    rec = h.audit_record
    assert rec is not None and rec.passed
    s.close()


def test_dashboard_renders_self_contained_html(catalog, tmp_path):
    from repro.serve import render_dashboard, write_dashboard
    cfg = _telemetry_cfg(tmp_path, trace_sample=1.0,
                         slo_targets=(SloTarget(p95_latency_s=1e-9),))
    s = Session(catalog, seed=5, config=cfg)
    s.submit(HERD_SQL)
    s.submit(HERD_SQL)
    s.drain()
    html_doc = render_dashboard(s, title="test run")
    assert html_doc.startswith("<!doctype html>")
    assert "test run" in html_doc
    key = s.template_key(HERD_SQL)
    assert key in html_doc                      # template table row
    assert "BREACHED" in html_doc               # the impossible SLO
    assert "svg" in html_doc                    # sparkline present
    assert "pilotdb_slo_breaches_total" in html_doc  # registry text
    assert "http://" not in html_doc and "https://" not in html_doc
    out = write_dashboard(str(tmp_path / "dash.html"), s)
    assert out is not None
    assert (tmp_path / "dash.html").read_text(
        encoding="utf-8").startswith("<!doctype html>")
    # write failures degrade to None, never raise
    assert write_dashboard("/nonexistent-dir-for-pilotdb-tests/d.html",
                           s) is None
    # a telemetry-off session still renders (empty-state sections)
    plain = Session(catalog, seed=5)
    doc = render_dashboard(plain)
    assert "Telemetry is off" in doc
    plain.close()
    s.close()
