"""Observability layer (repro.obs): tracing, metrics registry, auditor.

The hard contracts under test:

* tracing OFF (the default) carries NO trace object anywhere — today's
  path, byte for byte;
* tracing ON changes no answer: bit-identical to the untraced equal-seed
  session across solo / herd / batched / cached / staged / sharded runs
  (spans only observe — perf_counter + attr dicts);
* every COMPLETED, FALLBACK, or FAILED query yields a CLOSED span tree
  (open_spans() == []), including mid-group captured failures, and the
  ErrorFrame path still terminates a blocked stream();
* the metrics registry absorbs the scattered counters (collectors match
  their sources) and renders Prometheus text; collectors die with their
  owners;
* audit mode perturbs nothing (bit-identical answers, untouched cache
  keys) while recording observed <= promised error for honest runs.
"""

import json

import numpy as np
import pytest

from repro.api import ErrorFrame, FinalFrame, PilotFrame, Session, \
    SessionConfig
from repro.core.taqa import PilotDB
from repro.engine.datagen import tpch_catalog
from repro.obs import GLOBAL, GuaranteeAuditor, MetricsRegistry, QueryTrace
from repro.obs import trace as trace_mod
from repro.obs.audit import provenance_of
from repro.serve.sql_gateway import SqlGateway

HERD_SQL = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            "WHERE l_quantity < 24 ERROR 8% CONFIDENCE 95%")
GROUPED_SQL = ("SELECT SUM(l_quantity) AS q, COUNT(*) AS n FROM lineitem "
               "WHERE l_quantity < 30 GROUP BY l_returnflag MAXGROUPS 3 "
               "ERROR 10% CONFIDENCE 90%")

SERIAL_CFG = SessionConfig(async_workers=0, share_pilots=False,
                           result_cache_size=0)
NOCACHE_CFG = SessionConfig(async_workers=4, result_cache_size=0)
TRACE_SERIAL = SessionConfig(async_workers=0, share_pilots=False,
                             result_cache_size=0, tracing=True)
TRACE_HERD = SessionConfig(async_workers=4, result_cache_size=0,
                           tracing=True)


@pytest.fixture(scope="module")
def catalog():
    return tpch_catalog(scale_rows=200_000, block_rows=32, seed=0)


def _assert_bitwise(a, b):
    assert np.array_equal(a.values, b.values)
    assert np.array_equal(a.group_present, b.group_present)
    assert list(a.names) == list(b.names)


# ---------------------------------------------------------------------------
# Zero-overhead default: tracing OFF is today's path
# ---------------------------------------------------------------------------

def test_tracing_off_by_default(catalog):
    s = Session(catalog, seed=3, config=SERIAL_CFG)
    h = s.sql(HERD_SQL)
    assert h._trace is None
    assert h.trace() is None and h.trace("chrome") is None
    assert trace_mod.active() is None
    # instrumentation points degrade to the shared no-op span
    assert trace_mod.span("anything") is trace_mod.NULL_SPAN


def test_trace_format_validated(catalog):
    s = Session(catalog, seed=3, config=TRACE_SERIAL)
    h = s.sql(HERD_SQL)
    with pytest.raises(ValueError):
        h.trace(fmt="protobuf")


# ---------------------------------------------------------------------------
# Bit-identity: tracing observes, never steers
# ---------------------------------------------------------------------------

def test_traced_solo_bitwise_identical(catalog):
    plain = Session(catalog, seed=3, config=SERIAL_CFG).sql(HERD_SQL)
    traced = Session(catalog, seed=3, config=TRACE_SERIAL).sql(HERD_SQL)
    assert traced.fallback is None
    _assert_bitwise(traced.answer, plain.answer)


def test_traced_herd_bitwise_identical(catalog):
    solo = Session(catalog, seed=11, config=SERIAL_CFG).sql(HERD_SQL)
    rt = Session(catalog, seed=11, config=TRACE_HERD)
    handles = [rt.submit(HERD_SQL) for _ in range(5)]
    p0 = rt.executor.pilots_run
    rt.drain()
    assert rt.executor.pilots_run - p0 == 1  # tracing kept pilot sharing
    for h in handles:
        _assert_bitwise(h.answer, solo.answer)
        assert h._trace is not None and h._trace.open_spans() == []
    rt.close()


def test_traced_batched_finals_bitwise(catalog):
    template = ("SELECT SUM(l_extendedprice) AS rev FROM lineitem "
                "WHERE l_quantity < {} ERROR 10% CONFIDENCE 90%")
    cuts = [18, 24, 30, 36]
    serial = Session(catalog, seed=9, config=SERIAL_CFG)
    want = {c: serial.sql(template.format(c)).answer for c in cuts}
    rt = Session(catalog, seed=9, config=TRACE_HERD)
    handles = {c: rt.submit(template.format(c)) for c in cuts}
    rt.drain()
    for c, h in handles.items():
        _assert_bitwise(h.answer, want[c])
        assert h._trace.open_spans() == []
    rt.close()


def test_traced_cached_reissue_bitwise_and_provenance(catalog):
    s = Session(catalog, seed=13, config=SessionConfig(tracing=True))
    first = s.sql(HERD_SQL)
    again = s.sql(HERD_SQL)
    assert again.cached
    _assert_bitwise(again.answer, first.answer)
    assert again._trace.open_spans() == []
    hits = [sp for sp in again._trace.find("cache_lookup")
            if sp.attrs.get("hit")]
    assert hits  # the trace recorded the cache serve
    assert provenance_of(again) == "cached"
    s.close()


@pytest.mark.parametrize("shards", [1, 2])
def test_traced_sharded_bitwise_with_fanout_span(catalog, shards):
    mono = Session(catalog, seed=31, config=SERIAL_CFG).sql(GROUPED_SQL)
    s = Session(seed=31, config=TRACE_SERIAL)
    for name, tab in catalog.items():
        s.register_table(name, tab,
                         shards=shards if name == "lineitem" else None)
    h = s.sql(GROUPED_SQL)
    _assert_bitwise(h.answer, mono.answer)
    fanouts = h._trace.find("shard_fanout")
    if mono.fallback is None:
        assert fanouts and fanouts[0].attrs["shards"] == shards
        assert "+dist" in provenance_of(h)


def test_traced_staged_bitwise_with_staged_tags(catalog):
    def _run(rates, cfg):
        s = Session(seed=41, config=cfg)
        for name, tab in catalog.items():
            s.register_table(name, tab,
                             staged_rates=rates if name == "lineitem"
                             else None)
        return s, s.sql(HERD_SQL)

    _, ref = _run([1e-9], SERIAL_CFG)      # ladder that never serves
    s, hot = _run(True, TRACE_SERIAL)      # default ladder, traced
    assert s.executor.staged_info()["hits"] > 0
    _assert_bitwise(hot.answer, ref.answer)
    tagged = [sp for sp in hot._trace.find("scan")
              if sp.attrs.get("staged")]
    assert tagged  # staged-rung serves are visible in the trace
    assert "+staged" in provenance_of(hot)


# ---------------------------------------------------------------------------
# Span tree: vocabulary, closure, export
# ---------------------------------------------------------------------------

def test_solo_span_vocabulary_and_attrs(catalog):
    s = Session(catalog, seed=3, config=TRACE_SERIAL)
    h = s.sql(HERD_SQL)
    tr = h._trace
    assert tr.status == "ok" and tr.open_spans() == []
    names = set(tr.span_names())
    assert {"query", "parse", "lower", "pilot", "rate_solve",
            "final", "deliver"} <= names
    pilot, = tr.find("pilot")
    assert pilot.attrs["table"] == "lineitem"
    assert pilot.attrs["scanned_bytes"] > 0
    assert pilot.attrs["shared"] is False
    final, = tr.find("final")
    assert final.attrs["scanned_bytes"] > 0
    lower, = tr.find("lower")
    assert lower.attrs["seed"] == h.seed
    # nested engine scans attach under their stages
    assert any(c.name == "scan" for c in pilot.children)


def test_scheduled_drain_closes_schedule_span(catalog):
    s = Session(catalog, seed=3, config=TRACE_HERD)
    h = s.submit(HERD_SQL)
    assert "schedule" in h._trace.open_spans()
    s.drain()
    assert h._trace.open_spans() == []
    sched, = h._trace.find("schedule")
    assert sched.t1 is not None
    s.close()


def test_trace_exports_json_and_chrome(catalog):
    s = Session(catalog, seed=3, config=TRACE_SERIAL)
    h = s.sql(HERD_SQL)
    tree = h.trace()
    json.dumps(tree)  # JSON-able throughout
    assert tree["status"] == "ok" and tree["root"]["name"] == "query"
    assert tree["root"]["attrs"]["sql"] == HERD_SQL
    events = h.trace("chrome")
    json.dumps(events)
    assert all(e["ph"] == "X" and e["pid"] == h.query_id for e in events)
    assert {e["name"] for e in events} >= {"query", "pilot", "final"}
    # durations in microseconds, start times relative to the trace
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in events)


def test_failed_query_trace_closed_with_error_status(catalog):
    s = Session(catalog, seed=3, config=TRACE_HERD)
    h = s.submit("SELECT COUNT(*) AS n FROM not_a_table GROUP BY g")
    s.drain()
    assert h.status == "failed"
    assert h._trace.status == "error" and h._trace.open_spans() == []
    assert h.trace()["root"]["attrs"]["error"] == h.error
    s.close()


def test_mid_group_failure_traced_closes_spans_and_error_frame(
        catalog, monkeypatch):
    """Satellite: a mid-group failure under tracing must close the failed
    member's span tree AND emit its terminal ErrorFrame — stream() ends."""
    base = ("SELECT SUM(l_extendedprice) AS rev FROM lineitem "
            "WHERE l_shipdate < 2000 ")
    sqls = [base + f"ERROR {e}% CONFIDENCE 95%" for e in (8, 7, 6)]
    session = Session(catalog, seed=5, config=TRACE_HERD)
    real = PilotDB.prepare_final

    def flaky(self, q, spec, outcome, seed, shared=False):
        if abs(spec.error - 0.07) < 1e-12:
            raise RuntimeError("worker exploded mid-group")
        return real(self, q, spec, outcome, seed, shared=shared)

    monkeypatch.setattr(PilotDB, "prepare_final", flaky)
    handles = [session.submit(s, stream=True) for s in sqls]
    session.drain()
    assert [h.status for h in handles] == ["done", "failed", "done"]
    for h in handles:
        assert h._trace.open_spans() == []  # every tree closed
        frames = list(h.stream())           # terminates, never hangs
        assert frames[-1].terminal
    failed = handles[1]
    assert failed._trace.status == "error"
    assert isinstance(failed.frames()[-1], ErrorFrame)
    # siblings still completed with full span trees and pilot sharing
    assert {"pilot", "final"} <= set(handles[0]._trace.span_names())
    session.close()


def test_trace_mechanics_null_span_after_finish():
    tr = QueryTrace(0)
    with tr.span("a", k=1) as sp:
        assert tr.open_spans() == ["query", "a"]
        sp.set(extra=2)
    assert tr.open_spans() == ["query"]
    tr.finish("ok")
    assert tr.finished and tr.open_spans() == []
    # post-finish instrumentation degrades to no-ops
    assert tr.span("late") is trace_mod.NULL_SPAN
    before = tr.span_names()
    tr.record("late2")
    tr.finish("error")  # idempotent: first status wins
    assert tr.span_names() == before and tr.status == "ok"


def test_trace_span_error_status_on_exception():
    tr = QueryTrace(1)
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("bad")
    sp, = tr.find("boom")
    assert sp.status == "error" and "RuntimeError: bad" in sp.attrs["error"]
    assert not sp.open


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_registry_instruments_get_or_create_and_kinds():
    reg = MetricsRegistry()
    c = reg.counter("x_total", "help text")
    c.inc()
    c.inc(2)
    assert reg.counter("x_total").value == 3
    g = reg.gauge("x_now")
    g.set(1.5)
    assert g.value == 1.5
    hist = reg.histogram("x_seconds")
    hist.observe(0.003)
    hist.observe(0.3)
    assert hist.count == 2 and hist.max == 0.3
    with pytest.raises(TypeError):
        reg.gauge("x_total")  # kind mismatch is a bug, not a new metric


def test_registry_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests").inc(4)
    reg.histogram("lat_seconds", buckets=(0.1, 1.0)).observe(0.5)
    reg.register_collector("cache", lambda: {"hits": 2, "nested": {"n": 1},
                                             "name": "dropme"})
    text = reg.to_text()
    assert "# TYPE req_total counter" in text
    assert "req_total 4" in text
    assert '# HELP req_total requests' in text
    assert 'lat_seconds_bucket{le="0.1"} 0' in text
    assert 'lat_seconds_bucket{le="1"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 1' in text
    assert "lat_seconds_count 1" in text
    # collector snapshots flatten to path-joined gauges; strings dropped
    assert "cache_hits 2" in text and "cache_nested_n 1" in text
    assert "dropme" not in text
    assert text.endswith("\n")


def test_registry_collector_dies_with_owner():
    reg = MetricsRegistry()

    class Owner:
        pass

    o = Owner()
    reg.register_collector("mine", lambda: {"v": 1}, owner=o)
    assert reg.tree() == {"mine": {"v": 1}}
    del o
    assert reg.tree() == {}  # pruned at read, never a dead scrape


def test_session_collectors_match_sources(catalog):
    s = Session(catalog, seed=5)
    s.sql(HERD_SQL)
    tree = s.metrics.tree()
    info = s.compile_cache_info()
    assert tree["compile_cache"]["hits"] == info.hits
    assert tree["compile_cache"]["misses"] == info.misses
    rc = s.result_cache_info()
    assert tree["result_cache"]["hits"] == rc.hits
    assert tree["result_cache"]["bytes_used"] == rc.bytes_used
    assert tree["staged"]["tables"] == {}
    assert tree["runtime"]["queries_run"] == s.executor.queries_run
    assert tree["runtime"]["pilots_run"] == s.executor.pilots_run
    assert tree["audit"] == {"runs": 0, "violations": 0, "errors": 0,
                             "max_error_ratio": 0.0}
    s.close()


def test_drain_counters_land_in_registry(catalog):
    s = Session(catalog, seed=5, config=NOCACHE_CFG)
    s.submit(HERD_SQL)
    s.submit(HERD_SQL)
    s.drain()
    assert s.metrics.counter("pilotdb_drains_total").value == 1
    assert s.metrics.counter("pilotdb_drained_queries_total").value == 2
    assert s.metrics.histogram("pilotdb_drain_wall_seconds").count == 1
    s.close()


def test_gateway_metrics_text_includes_gateway_counters(catalog):
    s = Session(catalog, seed=5)
    gw = SqlGateway(s)
    gw.submit("c0", HERD_SQL)
    gw.run()
    text = gw.metrics_text()
    assert f"{gw._collector_name}_requests 1" in text
    assert "compile_cache_hits" in text
    assert "result_cache_bytes_used" in text
    s.close()


# ---------------------------------------------------------------------------
# Guarantee auditor
# ---------------------------------------------------------------------------

def test_audit_mode_bit_identical_and_honest(catalog):
    plain = Session(catalog, seed=7, config=SERIAL_CFG).sql(HERD_SQL)
    audit_cfg = SessionConfig(async_workers=0, share_pilots=False,
                              result_cache_size=0, tracing=True, audit=True)
    s = Session(catalog, seed=7, config=audit_cfg)
    h = s.sql(HERD_SQL)
    # non-perturbation: the audited answer is the unaudited one, bitwise
    _assert_bitwise(h.answer, plain.answer)
    rec = h.audit_record
    assert rec is not None and rec.skipped is None
    assert rec.passed and rec.observed_error <= rec.promised_error
    assert 0.0 <= rec.error_ratio <= 1.0
    assert rec.provenance == "fresh"
    summ = s.auditor.summary()
    assert summ["runs"] == 1 and summ["violations"] == 0
    assert summ["max_error_ratio"] == rec.error_ratio
    # the ratio landed in the registry histogram + gauge
    assert s.metrics.histogram("pilotdb_audit_error_ratio").count == 1
    assert s.metrics.gauge(
        "pilotdb_audit_max_error_ratio").value == rec.error_ratio


def test_audit_skips_exact_answers_without_second_scan(catalog):
    s = Session(catalog, seed=7, config=SessionConfig(audit=True))
    h = s.sql("SELECT COUNT(*) AS n FROM lineitem")  # no spec: exact
    rec = h.audit_record
    assert rec.skipped == "answer is exact"
    assert rec.observed_error == 0.0 and rec.passed
    assert rec.exact_wall_s == 0.0  # no extra scan was paid
    assert s.auditor.summary()["skipped_exact"] == 1
    s.close()


def test_audit_grouped_checks_every_covered_group(catalog):
    cfg = SessionConfig(async_workers=0, share_pilots=False,
                        result_cache_size=0, audit=True)
    s = Session(catalog, seed=21, config=cfg)
    h = s.sql(GROUPED_SQL)
    rec = h.audit_record
    if h.fallback is None:
        assert rec.skipped is None
        assert rec.groups_checked >= 1
        assert rec.passed


def test_audit_never_raises_into_query_path(catalog, monkeypatch):
    cfg = SessionConfig(async_workers=0, share_pilots=False,
                        result_cache_size=0, audit=True)
    s = Session(catalog, seed=7, config=cfg)

    def broken_exact(self, q):
        raise RuntimeError("audit scan died")

    monkeypatch.setattr(PilotDB, "exact", broken_exact)
    h = s.sql(HERD_SQL)
    assert h.status == "done"  # the client still got its answer
    assert h.audit_record is None
    assert s.auditor.summary()["errors"] == 1
    assert s.metrics.counter("pilotdb_audit_errors_total").value == 1


def test_explain_reports_guarantee_and_audit(catalog):
    cfg = SessionConfig(async_workers=0, share_pilots=False,
                        result_cache_size=0, tracing=True, audit=True)
    s = Session(catalog, seed=7, config=cfg)
    h = s.sql(HERD_SQL)
    text = h.explain()
    assert f"Query {h.query_id}:" in text
    assert "ERROR 8% CONFIDENCE 95%" in text
    assert "provenance: fresh" in text
    assert "pilot: table=lineitem" in text
    assert "solved rates" in text
    assert "audit: observed=" in text and "[OK]" in text


def test_explain_failed_handle(catalog):
    s = Session(catalog, seed=3)
    h = s.failed_handle("SELEKT 1", "SqlSyntaxError: nope")
    text = h.explain()
    assert "FAILED" in text and "SqlSyntaxError" in text
    s.close()


def test_global_registry_exists():
    # the process-wide registry is importable and scrapes cleanly even
    # when empty
    assert isinstance(GLOBAL.to_text(), str)
