import os
import sys

# Make `import repro` work without installation.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests and benches must see exactly ONE device; only launch/dryrun.py sets
# the 512-device XLA flag (and does so before any jax import).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
