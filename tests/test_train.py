"""Training substrate: optimizer, train_step, checkpoint/restart, elastic,
gradient compression, data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.models import build_model
from repro.train import checkpoint as ckpt
from repro.train import compression
from repro.train.data import (DataState, TokenPipeline, make_domain_metadata,
                              plan_mixture_weights)
from repro.train.elastic import StragglerWatchdog, plan_mesh
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from repro.train.step import TrainState, cross_entropy, init_train_state, make_train_step

RNG = jax.random.PRNGKey(0)


def tiny_model():
    return build_model(ARCHITECTURES["internlm2-1.8b"].reduced())


def tiny_batch(cfg, b=4, s=16, seed=3):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# -- optimizer -----------------------------------------------------------------

def test_lr_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(cfg, jnp.int32(1))) < 2e-4
    assert float(lr_schedule(cfg, jnp.int32(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr_schedule(cfg, jnp.int32(100))) == pytest.approx(1e-4, rel=1e-4)


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0, total_steps=200,
                      min_lr_ratio=1.0)
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = init_opt_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_train_step_loss_decreases():
    model = tiny_model()
    state = init_train_state(model, RNG)
    step = make_train_step(model, AdamWConfig(lr=3e-3, warmup_steps=0,
                                              weight_decay=0.0))
    step = jax.jit(step)
    batch = tiny_batch(model.cfg)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3  # memorizes a fixed batch fast
    assert np.isfinite(losses).all()


def test_microbatch_accumulation_matches_full_batch():
    model = tiny_model()
    state = init_train_state(model, RNG)
    batch = tiny_batch(model.cfg, b=4)
    s1 = make_train_step(model, AdamWConfig(warmup_steps=0), microbatches=1)
    s2 = make_train_step(model, AdamWConfig(warmup_steps=0), microbatches=2)
    st1, m1 = s1(state, batch)
    st2, m2 = s2(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).max()),
                     st1.params, st2.params)
    assert max(jax.tree.leaves(d)) < 5e-3  # same update up to accumulation fp error


def test_cross_entropy_masks_padded_vocab():
    logits = jnp.zeros((1, 2, 8))
    labels = jnp.array([[1, 2]])
    base = cross_entropy(logits, labels, vocab_size=8)
    # putting huge mass on padded columns must not help once masked
    spiked = logits.at[..., 6:].set(50.0)
    masked = cross_entropy(spiked, labels, vocab_size=6)
    assert float(masked) == pytest.approx(float(cross_entropy(
        jnp.zeros((1, 2, 6)), labels, 6)), rel=1e-5)
    assert np.isfinite(float(base))


# -- compression ------------------------------------------------------------

def test_quantize_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, 1000).astype(np.float32))
    q, s = compression.quantize(x)
    back = compression.dequantize(q, s)
    assert float(jnp.abs(back - x).max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_contracts():
    """With error feedback, the cumulative applied update tracks the true
    gradient sum (residual stays bounded)."""
    rng = np.random.default_rng(1)
    residual = jnp.zeros(64)
    applied = jnp.zeros(64)
    total = jnp.zeros(64)
    for i in range(50):
        g = jnp.asarray(rng.normal(0, 1, 64).astype(np.float32))
        ghat, residual, _ = compression.compress_leaf(g, residual)
        applied = applied + ghat
        total = total + g
    # applied + residual == total exactly (telescoping identity)
    np.testing.assert_allclose(np.asarray(applied + residual),
                               np.asarray(total), rtol=1e-4, atol=1e-4)
    assert float(jnp.abs(residual).max()) < 1.0


def test_compressed_training_still_converges():
    model = tiny_model()
    state = init_train_state(model, RNG, compress=True)
    step = make_train_step(model, AdamWConfig(lr=3e-3, warmup_steps=0,
                                              weight_decay=0.0), compress=True)
    step = jax.jit(step)
    batch = tiny_batch(model.cfg)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.25


# -- checkpointing ------------------------------------------------------------

def test_checkpoint_save_restore_roundtrip(tmp_path):
    model = tiny_model()
    state = init_train_state(model, RNG)
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, state.params, extra={"data": {"step": 7}})
    assert ckpt.latest_step(d) == 7
    target = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), state.params)
    restored, extra = ckpt.restore(d, 7, target)
    same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), restored, state.params)
    assert all(jax.tree.leaves(same))
    assert extra["data"]["step"] == 7


def test_checkpoint_gc_keeps_last(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": jnp.ones(4)}
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, tree, keep=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"w": jnp.ones(4)})
    with pytest.raises(ValueError):
        ckpt.restore(d, 1, {"w": jnp.ones(5)})


def test_checkpoint_restore_with_resharding(tmp_path):
    """Elastic path: restore under a different (1-device) mesh/sharding."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(d, 1, tree)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    restored, _ = ckpt.restore(d, 1, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


# -- elastic -----------------------------------------------------------------

def test_plan_mesh_shrinks_on_failure():
    full = plan_mesh(512, tp=16, per_replica_batch=8, prefer_pods=True)
    assert full.shape == (2, 16, 16) and full.global_batch == 256
    degraded = plan_mesh(512 - 16, tp=16, per_replica_batch=8)
    assert degraded.shape == (31, 16)
    assert degraded.global_batch == 31 * 8
    with pytest.raises(ValueError):
        plan_mesh(8, tp=16)


def test_straggler_watchdog_flags_and_escalates():
    w = StragglerWatchdog(threshold=2.0, warmup=2)
    for _ in range(6):
        assert not w.observe(1.0)
    assert w.observe(5.0)  # straggler
    assert not w.should_remesh
    w.observe(5.0)
    w.observe(5.0)
    assert w.should_remesh
    # baseline not polluted by outliers
    assert w.ewma == pytest.approx(1.0, rel=1e-6)


# -- data pipeline ------------------------------------------------------------

def test_pipeline_deterministic_resume():
    p1 = TokenPipeline(1000, batch=4, seq=8, seed=5)
    batches = [p1.next_batch() for _ in range(5)]
    # resume from step 3
    p2 = TokenPipeline(1000, batch=4, seq=8, seed=5)
    p2.state = DataState.from_json(
        {"seed": 5, "step": 3, "cursors": {"default": 0}})
    resumed = p2.next_batch()
    np.testing.assert_array_equal(resumed["tokens"], batches[3]["tokens"])


def test_aqp_planned_mixture_weights():
    meta = make_domain_metadata({"web": 2000, "code": 1000, "books": 1000},
                                block_rows=64, seed=1)
    weights, report = plan_mixture_weights(meta, 3, error=0.1, confidence=0.9)
    assert set(weights) == {0, 1, 2}
    assert sum(weights.values()) == pytest.approx(1.0)
    # domain 2 ("web" is code 2? sorted: books=0, code=1, web=2) — quality
    # beta(2+code, 2) increases with code, so weights must be ordered
    assert weights[2] > weights[0]
    assert report.fallback is None  # the AQP plan actually ran
    # mixture drives the pipeline
    pipe = TokenPipeline(1000, batch=8, seq=4,
                         domains={"books": weights[0], "code": weights[1],
                                  "web": weights[2]})
    b = pipe.next_batch()
    assert b["tokens"].shape == (8, 4)
