"""Materialized block-sample catalog (repro.engine.staged).

The non-negotiable contract: a table registered with ``staged_rates=``
pins ONE content-derived staging realization, and every block draw of that
table — staged hit, fresh miss, pilot, final, monolithic or sharded —
replays it.  Answers are therefore bit-identical whether a query is served
from pre-gathered rung arrays or falls back to a fresh draw (rate above
the top rung, evicted arrays, non-routable plan shapes), for every ladder
configuration and every shard count.  ``staged_rates=None`` stages nothing
and reproduces the unstaged behavior exactly.

The *reference* in these tests is a session/executor whose ladder can
never serve (a single rung at rate 1e-9): every query then misses to a
fresh draw under the SAME pinned seed, exercising today's gather path.
"""

import numpy as np
import pytest

from repro.api import Session, SessionConfig
from repro.dist import DistExecutor
from repro.engine import logical as L
from repro.engine.datagen import tpch_catalog
from repro.engine.executor import EmptySampleError, Executor
from repro.engine.expr import And, Col
from repro.engine.sampling import draw_block_ids, subdraw_positions
from repro.engine.staged import (DEFAULT_STAGED_RATES, build_ladder,
                                 prepare_mono_subdraw, validate_rates)

ROWS, BLOCK_ROWS = 24_000, 64
SEED = 11

# A ladder whose single rung covers no realistic rate: every query misses
# to a fresh draw under the ladder's pinned seed — the bitwise reference.
NEVER = [1e-9]
LADDER = [0.01, 0.04, 0.16, 0.5]


@pytest.fixture(scope="module")
def catalog():
    return tpch_catalog(ROWS, BLOCK_ROWS, seed=3)


def q6_base(cap=24):
    pred = And(Col("l_shipdate").between(100, 1500), Col("l_quantity") < cap)
    return L.Aggregate(
        child=L.Filter(L.Scan("lineitem"), pred),
        aggs=(L.AggSpec("sum", Col("l_extendedprice") * Col("l_discount"),
                        "rev"),
              L.AggSpec("count", None, "cnt"),
              L.AggSpec("avg", Col("l_quantity"), "aq")),
        group_by="l_returnflag", max_groups=3)


def q6_plan(seed, rate=0.12, cap=24):
    return L.rewrite_scans(
        q6_base(cap), {"lineitem": L.SampleClause("block", rate, seed)})


def staged_executor(catalog, rates, *, seed=0, **kw):
    ex = Executor(dict(catalog), **kw)
    ex.register_staged("lineitem", rates, seed=seed)
    return ex


# ---------------------------------------------------------------------------
# The restriction invariant + ladder construction
# ---------------------------------------------------------------------------

def test_subdraw_is_restriction_of_rung():
    n, seed = 5000, 42
    rung_ids = draw_block_ids(n, 0.16, seed)
    for rate in (0.001, 0.01, 0.04, 0.16):
        sub_ids, positions = subdraw_positions(rung_ids, n, rate, seed)
        # the sub-draw IS the fresh draw at that rate (same realization) ...
        np.testing.assert_array_equal(sub_ids, draw_block_ids(n, rate, seed))
        # ... and every sub-drawn id is addressed by its rung position
        np.testing.assert_array_equal(rung_ids[positions], sub_ids)


def test_validate_rates():
    assert validate_rates([0.16, 0.01, 0.04]) == (0.01, 0.04, 0.16)
    assert validate_rates([1.0]) == (1.0,)
    with pytest.raises(ValueError):
        validate_rates([])
    with pytest.raises(ValueError):
        validate_rates([0.0])
    with pytest.raises(ValueError):
        validate_rates([1.5])


def test_rung_selection_smallest_covering(catalog):
    lad = build_ladder("lineitem", catalog["lineitem"], LADDER, 7,
                       "auto", dict(catalog))
    assert lad.rung_for(0.005).rate == 0.01
    assert lad.rung_for(0.01).rate == 0.01   # exact match, no eps rejection
    assert lad.rung_for(0.05).rate == 0.16
    assert lad.rung_for(0.3).rate == 0.5
    assert lad.rung_for(0.7) is None          # above the top rung
    # rung arrays are the table's sampled slabs with global lineage intact
    rung = lad.rung_for(0.01)
    assert rung.table.num_blocks == len(rung.ids)
    assert rung.table.num_origin_blocks == catalog["lineitem"].num_blocks
    np.testing.assert_array_equal(
        np.asarray(rung.table.block_id).reshape(-1, BLOCK_ROWS)[:, 0],
        rung.ids)


def test_prepare_mono_subdraw_memoizes(catalog):
    lad = build_ladder("lineitem", catalog["lineitem"], LADDER, 7,
                       "auto", dict(catalog))
    rung = lad.rung_for(0.04)
    s1 = prepare_mono_subdraw(lad, rung, 0.03)
    s2 = prepare_mono_subdraw(lad, rung, 0.03)
    assert s1 is s2  # warm path skips the host RNG entirely
    # the forced physical count matches the fresh path's bucketing
    from repro.engine.sampling import bucket_blocks
    assert s1.n_phys == min(bucket_blocks(max(s1.n_real, 1)),
                            catalog["lineitem"].num_blocks)
    assert len(s1.phys) == s1.n_phys


# ---------------------------------------------------------------------------
# Executor-level bit-identity: finals and pilots
# ---------------------------------------------------------------------------

def test_staged_final_bit_identical_and_counted(catalog):
    ref = staged_executor(catalog, NEVER)
    hot = staged_executor(catalog, LADDER)
    for i, rate in enumerate((0.01, 0.035, 0.12, 0.4)):
        plan = q6_plan(seed=100 + i, rate=rate, cap=20 + i)
        a_ref = ref.execute(plan)
        a_hot = hot.execute(plan)
        np.testing.assert_array_equal(np.asarray(a_ref.values),
                                      np.asarray(a_hot.values))
        np.testing.assert_array_equal(np.asarray(a_ref.group_present),
                                      np.asarray(a_hot.group_present))
    assert hot.staged.hits == 4 and hot.staged.misses == 0
    assert ref.staged.hits == 0 and ref.staged.misses == 4
    info = hot.compile_cache_info()
    assert info.staged_hits == 4 and info.staged_misses == 0


def test_staged_rate_above_top_rung_falls_back_bit_identically(catalog):
    ref = staged_executor(catalog, NEVER)
    hot = staged_executor(catalog, [0.01, 0.04])   # top rung 4%
    plan = q6_plan(seed=5, rate=0.3)               # required rate above it
    a_ref = ref.execute(plan)
    a_hot = hot.execute(plan)
    np.testing.assert_array_equal(np.asarray(a_ref.values),
                                  np.asarray(a_hot.values))
    assert hot.staged.hits == 0 and hot.staged.misses == 1


def test_staged_pilot_stats_bit_identical(catalog):
    ref = staged_executor(catalog, NEVER)
    hot = staged_executor(catalog, LADDER)
    base = q6_base()  # pilots run on the unsampled plan
    p_ref = ref.execute_pilot(base, "lineitem", 0.03, seed=123)
    p_hot = hot.execute_pilot(base, "lineitem", 0.03, seed=123)
    assert p_ref.n_sampled_blocks == p_hot.n_sampled_blocks > 0
    np.testing.assert_array_equal(np.asarray(p_ref.block_sums),
                                  np.asarray(p_hot.block_sums))
    np.testing.assert_array_equal(np.asarray(p_ref.group_present),
                                  np.asarray(p_hot.group_present))
    assert hot.staged.hits == 1 and ref.staged.misses == 1


def test_staged_empty_subdraw_raises_like_fresh(catalog):
    # a rate far below 1/num_blocks: the pinned realization has no block
    # below the threshold, so BOTH paths see an empty sample
    ref = staged_executor(catalog, NEVER)
    hot = staged_executor(catalog, LADDER)
    rate = 1e-7
    assert len(draw_block_ids(catalog["lineitem"].num_blocks, rate, 0)) == 0
    with pytest.raises(EmptySampleError):
        hot.execute(q6_plan(seed=1, rate=rate))
    with pytest.raises(EmptySampleError):
        ref.execute(q6_plan(seed=1, rate=rate))
    assert hot.staged.hits == 1  # the staged route served the empty verdict


def test_register_table_invalidates_stale_ladder(catalog):
    hot = staged_executor(catalog, LADDER)
    plan = q6_plan(seed=2, rate=0.1)
    old = hot.execute(plan)
    assert hot.staged.hits == 1
    # re-register with DIFFERENT data: the old rung arrays must not serve
    table = catalog["lineitem"]
    scaled = table.with_columns(
        {**table.columns, "l_extendedprice":
         table.columns["l_extendedprice"] * 2.0})
    hot.register_table("lineitem", scaled)
    assert hot.staged_info()["tables"] == {}  # ladder dropped, not re-staged
    # restaging on the new data serves the new values, bit-identical to a
    # pinned-seed fresh draw of the new data — never the stale rung arrays
    hot.register_staged("lineitem", NEVER, seed=0)
    fresh = hot.execute(plan)                 # fresh gather of the new data
    assert not np.array_equal(np.asarray(old.values),
                              np.asarray(fresh.values))
    hot.register_staged("lineitem", LADDER, seed=0)
    restaged = hot.execute(plan)
    np.testing.assert_array_equal(np.asarray(fresh.values),
                                  np.asarray(restaged.values))


def test_refresh_replicated_other_table(catalog):
    # a rung compiler replicates OTHER tables; re-registering one must
    # repoint the replicated entry (same sharing as the main catalog)
    hot = staged_executor(catalog, LADDER)
    orders = catalog["orders"]
    doubled = orders.with_columns(
        {**orders.columns,
         "o_totalprice": orders.columns["o_totalprice"] * 2.0})
    hot.register_table("orders", doubled)
    lad = hot.staged.ladder("lineitem")
    for rung in lad.rungs:
        assert rung.compiler.catalog["orders"] is doubled


def test_eviction_keeps_bit_identity(catalog):
    ref = staged_executor(catalog, NEVER)
    hot = staged_executor(catalog, LADDER)
    plan = q6_plan(seed=3, rate=0.1)
    before = hot.execute(plan)
    assert hot.staged.hits == 1
    # squeeze the budget: the ladder's arrays are dropped, the record stays
    hot.staged.max_bytes = 0
    with hot.staged._lock:
        hot.staged._enforce_budget()
    info = hot.staged_info()
    assert info["evictions"] == 1 and info["resident_bytes"] == 0
    assert info["tables"]["lineitem"]["resident_rates"] == []
    after = hot.execute(plan)     # misses to a fresh draw, same pinned seed
    assert hot.staged.misses == 1
    np.testing.assert_array_equal(np.asarray(before.values),
                                  np.asarray(after.values))
    np.testing.assert_array_equal(np.asarray(ref.execute(plan).values),
                                  np.asarray(after.values))


def test_staged_bytes_budget_evicts_lru(catalog):
    one = build_ladder("lineitem", catalog["lineitem"], [0.04], 0,
                       "auto", dict(catalog))
    nbytes = one.resident_bytes
    assert nbytes > 0
    ex = Executor(dict(catalog), staged_bytes=int(nbytes))
    ex.register_staged("lineitem", [0.04], seed=0)
    ex.register_staged("orders", [0.04], seed=0)   # busts the budget
    info = ex.staged_info()
    assert info["evictions"] == 1
    # the LRU victim is lineitem (registered first, never used since)
    assert info["tables"]["lineitem"]["resident_rates"] == []
    assert info["tables"]["orders"]["resident_rates"] == [0.04]


def test_batched_members_route_staged_solo(catalog):
    ref = staged_executor(catalog, NEVER)
    hot = staged_executor(catalog, LADDER)
    plans = [q6_plan(seed=10 + i, rate=0.08, cap=18 + i) for i in range(4)]
    ref_out = ref.execute_batch(plans)
    hot_out = hot.execute_batch(plans)
    for a, b in zip(ref_out, hot_out):
        np.testing.assert_array_equal(np.asarray(a.values),
                                      np.asarray(b.values))
    assert hot.staged.hits == 4


# ---------------------------------------------------------------------------
# Session-level: ladder configs x shard counts, herds, cached re-issues
# ---------------------------------------------------------------------------

SQLS = [
    "SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
    "WHERE l_quantity < 24 ERROR 8% CONFIDENCE 90%",
    "SELECT AVG(l_quantity) AS aq, COUNT(*) AS n FROM lineitem "
    "WHERE l_shipdate BETWEEN 100 AND 1500 GROUP BY l_returnflag "
    "MAXGROUPS 3 ERROR 10% CONFIDENCE 90%",
]


def _answers(catalog, staged_rates, shards):
    cfg = SessionConfig(large_table_rows=10_000, result_cache_size=0)
    session = Session(seed=SEED, config=cfg)
    session.register_table("lineitem", catalog["lineitem"], shards=shards,
                           staged_rates=staged_rates)
    out = []
    for sql in SQLS:
        a = session.sql(sql).result()
        out.append((np.asarray(a.values), np.asarray(a.group_present)))
    stats = dict(session.executor.staged.__dict__)
    session.close()
    return out, stats


def test_session_bit_identity_across_ladders_and_shards(catalog):
    ref, _ = _answers(catalog, NEVER, None)
    served_somewhere = False
    for rates in (LADDER, [0.5], True, NEVER):
        for shards in (None, 1, 2, 4):
            got, stats = _answers(catalog, rates, shards)
            for (rv, rp), (gv, gp) in zip(ref, got):
                np.testing.assert_array_equal(rv, gv)
                np.testing.assert_array_equal(rp, gp)
            if stats["hits"] > 0:
                served_somewhere = True
    assert served_somewhere  # the matrix exercised real staged serving


def test_session_staged_rates_none_is_todays_behavior(catalog):
    cfg = SessionConfig(large_table_rows=10_000)
    plain, staged_off = [], []
    for out in (plain, staged_off):
        session = Session(seed=SEED, config=cfg)
        session.register_table("lineitem", catalog["lineitem"],
                               staged_rates=None)
        for sql in SQLS:
            out.append(np.asarray(session.sql(sql).result().values))
        assert session.executor.staged_info()["tables"] == {}
        session.close()
    for a, b in zip(plain, staged_off):
        np.testing.assert_array_equal(a, b)


def test_session_herd_shared_pilots_and_cache_bit_identical(catalog):
    herd = ["SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            f"WHERE l_quantity < {cap} ERROR 8% CONFIDENCE 90%"
            for cap in (24, 24, 20, 22)]     # verbatim re-issue + constants
    results = {}
    for key, rates in (("ref", NEVER), ("hot", LADDER)):
        cfg = SessionConfig(large_table_rows=10_000, share_pilots=True,
                            batch_finals=True, result_cache_size=32)
        session = Session(seed=SEED, config=cfg)
        session.register_table("lineitem", catalog["lineitem"],
                               staged_rates=rates)
        handles = [session.submit(s) for s in herd]
        session.drain()
        first = [np.asarray(h.result().values) for h in handles]
        rerun = [np.asarray(session.sql(s).result().values) for s in herd]
        assert session.result_cache_info().hits > 0  # re-issues were cached
        results[key] = first + rerun
        if key == "hot":
            assert session.executor.staged.hits > 0
        session.close()
    for a, b in zip(results["ref"], results["hot"]):
        np.testing.assert_array_equal(a, b)


def test_session_validates_staged_rates_before_registering(catalog):
    session = Session(seed=SEED)
    with pytest.raises(ValueError):
        session.register_table("lineitem", catalog["lineitem"],
                               staged_rates=[2.0])
    assert "lineitem" not in session.executor.catalog  # rejected atomically
    session.close()


def test_session_exact_fallback_on_empty_staged_sample(catalog):
    # a 3-block toy table: the pinned realization at the pilot rate is
    # empty, the pilot escalates, and if everything stays empty the session
    # falls back to the exact answer — identically with and without rungs
    tiny = tpch_catalog(3 * BLOCK_ROWS, BLOCK_ROWS, seed=5)
    out = []
    for rates in (NEVER, LADDER):
        session = Session(seed=SEED,
                          config=SessionConfig(large_table_rows=64))
        session.register_table("lineitem", tiny["lineitem"],
                               staged_rates=rates)
        h = session.sql(SQLS[0])
        out.append(np.asarray(h.result().values))
        session.close()
    np.testing.assert_array_equal(out[0], out[1])


def test_gateway_payload_staged_section(catalog):
    from repro.serve import SqlGateway
    cfg = SessionConfig(large_table_rows=10_000)
    session = Session(seed=SEED, config=cfg)
    session.register_table("lineitem", catalog["lineitem"],
                           staged_rates=LADDER)
    gw = SqlGateway(session)
    gw.submit("c0", SQLS[0])
    gw.run()
    staged = gw.stats_payload()["staged"]
    assert staged["hits"] + staged["misses"] > 0
    assert staged["tables"]["lineitem"]["rates"] == LADDER
    assert staged["tables"]["lineitem"]["sharded"] is False
    session.close()


def test_dist_executor_staged_info_reports_sharded(catalog):
    ex = DistExecutor(dict(catalog))
    ex.register_sharded("lineitem", catalog["lineitem"], 3)
    ex.register_staged("lineitem", LADDER, seed=0)
    info = ex.staged_info()
    assert info["tables"]["lineitem"]["sharded"] is True
    assert info["tables"]["lineitem"]["resident_bytes"] > 0
