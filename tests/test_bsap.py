"""BSAP statistics: bound validity (coverage), Lemma 3.2/4.1, propagation."""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st

from repro.core import bsap, propagation
from repro.core.allocation import allocate


# -- t bounds on population block sums ---------------------------------------

def test_t_bounds_cover_population_total():
    rng = np.random.default_rng(0)
    pop = rng.gamma(2.0, 10.0, 4000)
    total = pop.sum()
    theta_p, delta = 0.05, 0.05
    cover_u = cover_l = 0
    trials = 400
    for _ in range(trials):
        keep = rng.random(4000) < theta_p
        y = pop[keep]
        if len(y) < 2:
            continue
        cover_u += bsap.upper_sum(y, 4000, delta) >= total
        cover_l += bsap.lower_sum(y, 4000, delta) <= total
    assert cover_u / trials >= 1 - delta - 0.03
    assert cover_l / trials >= 1 - delta - 0.03


def test_block_mean_lower_coverage():
    rng = np.random.default_rng(1)
    pop = rng.normal(50.0, 12.0, 3000)
    mean = pop.mean()
    delta = 0.1
    cover = 0
    trials = 500
    for _ in range(trials):
        y = rng.choice(pop, size=60, replace=False)
        cover += bsap.block_mean_lower(y, delta) <= mean
    assert cover / trials >= 1 - delta - 0.03


def test_degenerate_samples_give_infinite_bounds():
    assert bsap.block_mean_lower(np.array([1.0]), 0.05) == -math.inf
    assert bsap.upper_sum(np.array([1.0]), 10, 0.05) == math.inf
    uv = bsap.single_table_var_ub(np.array([1.0]), 0.1, 0.05, n_blocks=10)
    assert uv(0.05) == math.inf


# -- single-table variance bound (Lemma B.1 at block level) -------------------

def test_single_table_var_ub_dominates_empirical_variance():
    """U_V[θ] must upper-bound the true variance of N·ȳ_S w.h.p."""
    rng = np.random.default_rng(2)
    N, theta_p, theta, delta2 = 2000, 0.05, 0.03, 0.05
    pop = rng.gamma(3.0, 5.0, N)
    total = pop.sum()
    # empirical variance of the Hájek total under Bernoulli(theta)
    ests = []
    for _ in range(1500):
        keep = rng.random(N) < theta
        if keep.sum() == 0:
            continue
        ests.append(N * pop[keep].mean())
    emp_var = np.var(ests)
    # bound from pilots
    cover = 0
    trials = 200
    for _ in range(trials):
        keep = rng.random(N) < theta_p
        y = pop[keep]
        if len(y) < 2:
            continue
        uv = bsap.single_table_var_ub(y, theta_p, delta2, n_blocks=N)
        cover += uv(theta) >= emp_var
    assert cover / trials >= 1 - delta2 - 0.05
    assert np.mean(ests) == pytest.approx(total, rel=0.02)


def test_var_ub_monotone_decreasing_in_theta():
    rng = np.random.default_rng(3)
    y = rng.gamma(2.0, 3.0, 100)
    uv = bsap.single_table_var_ub(y, 0.05, 0.05, n_blocks=2000)
    vals = [uv(t) for t in (0.01, 0.02, 0.05, 0.1, 0.5)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))
    assert uv(1.0) == 0.0


# -- join variance bound (Lemma 4.8) ------------------------------------------

def test_join_var_ub_covers_empirical_ht_variance():
    """Two-table HT estimator variance is bounded by Lemma 4.8's U_V."""
    rng = np.random.default_rng(4)
    N1, N2 = 300, 40
    J = rng.gamma(2.0, 2.0, (N1, N2)) * (rng.random((N1, N2)) < 0.3)
    theta1, theta2, theta_p, delta2 = 0.2, 0.3, 0.2, 0.1
    # empirical HT variance
    ests = []
    for _ in range(1200):
        k1 = rng.random(N1) < theta1
        k2 = rng.random(N2) < theta2
        ests.append(J[np.ix_(k1, k2)].sum() / (theta1 * theta2))
    emp_var = np.var(ests)
    assert np.mean(ests) == pytest.approx(J.sum(), rel=0.05)
    cover = 0
    trials = 120
    for _ in range(trials):
        keep = rng.random(N1) < theta_p
        if keep.sum() < 2:
            continue
        uv = bsap.join_var_ub(J[keep], N1, delta2)
        cover += uv(theta1, theta2) >= emp_var
    assert cover / trials >= 1 - delta2 - 0.05


def test_join_var_ub_degenerates_to_single_table():
    rng = np.random.default_rng(5)
    J = rng.gamma(2.0, 2.0, (50, 10))
    uv = bsap.join_var_ub(J, 50, 0.1)
    # theta2 = 1: only the y1 (left-sampling) term remains
    v_left_only = uv(0.05, 1.0)
    assert v_left_only > 0
    # theta1 = 1: only the middle (right-sampling) term remains
    v_right_only = uv(1.0, 0.05)
    assert v_right_only > 0
    assert uv(0.05, 0.05) > max(v_left_only, v_right_only)


# -- Lemma 3.2 group coverage ------------------------------------------------

def test_group_coverage_rate_monte_carlo():
    """At the Lemma 3.2 rate, miss prob of a g-row group is <= p_f."""
    rng = np.random.default_rng(6)
    num_blocks, block_rows, g, p_f = 64, 4, 24, 0.10
    theta = bsap.group_coverage_rate(num_blocks, block_rows, g, p_f)
    assert 0 < theta <= 1
    n0 = math.ceil(g / block_rows)  # blocks the group occupies
    miss = 0
    trials = 3000
    for _ in range(trials):
        keep = rng.random(num_blocks) < theta
        miss += not keep[:n0].any()  # group packed in first n0 blocks
    assert miss / trials <= p_f + 0.02


def test_group_coverage_rate_edges():
    assert bsap.group_coverage_rate(2, 4, 100, 0.05) == 1.0
    r_small_g = bsap.group_coverage_rate(1000, 4, 400, 0.05)
    r_large_g = bsap.group_coverage_rate(1000, 4, 40, 0.05)
    assert r_small_g < r_large_g  # bigger groups are easier to cover


def test_group_miss_prob_inverse_consistency():
    theta = bsap.group_coverage_rate(500, 8, 160, 0.05)
    p = bsap.group_miss_prob_ub(theta, 500, 8, 160)
    assert p <= 0.05 + 1e-9


# -- Lemma 4.1 efficiency ratio ------------------------------------------------

def test_efficiency_ratio_heterogeneous_blocks():
    """Shuffled data: within-block var ≈ total var ⇒ ratio ≈ 0 (block wins)."""
    rng = np.random.default_rng(7)
    vals = rng.normal(0, 1, 64_000)
    r = bsap.efficiency_ratio(vals, 64)
    assert r < 2.0  # ≈ b * (1 - 1) = 0 up to noise


def test_efficiency_ratio_homogeneous_blocks():
    """Sorted data: within-block var ≈ 0 ⇒ ratio ≈ b (blocks redundant)."""
    rng = np.random.default_rng(8)
    vals = np.sort(rng.normal(0, 1, 64_000))
    r = bsap.efficiency_ratio(vals, 64)
    assert r > 50.0


def test_efficiency_ratio_constant_data():
    assert bsap.efficiency_ratio(np.ones(1000), 10) == 0.0


# -- naive row-level bounds (Lemma B.1) ----------------------------------------

def test_naive_row_bounds_valid_for_iid_rows():
    rng = np.random.default_rng(9)
    N = 50_000
    pop = rng.gamma(2.0, 5.0, N)
    theta_p, theta = 0.01, 0.02
    mean = pop.mean()
    # empirical variance of the sample mean at rate theta
    means = [pop[rng.random(N) < theta].mean() for _ in range(300)]
    emp_var = np.var(means)
    cover_L = cover_V = 0
    trials = 150
    for _ in range(trials):
        s = pop[rng.random(N) < theta_p]
        L_mu, U_V = bsap.naive_row_bounds(s.mean(), s.var(ddof=1), len(s),
                                          theta_p, 0.05, 0.05, exact_N=N)
        cover_L += L_mu <= mean
        cover_V += U_V(theta) >= emp_var
    assert cover_L / trials >= 0.9
    assert cover_V / trials >= 0.9


def test_naive_row_bounds_degenerate():
    L_mu, U_V = bsap.naive_row_bounds(1.0, 1.0, 1, 0.01, 0.05, 0.05)
    assert L_mu == -math.inf and U_V(0.5) == math.inf


# -- propagation rules (Table 2) -----------------------------------------------

@settings(max_examples=200, deadline=None)
@given(mu1=st.floats(0.5, 100), mu2=st.floats(0.5, 100),
       e1=st.floats(0.001, 0.5), e2=st.floats(0.001, 0.5),
       s1=st.sampled_from([-1.0, 1.0]), s2=st.sampled_from([-1.0, 1.0]))
def test_propagation_rules_are_upper_bounds(mu1, mu2, e1, e2, s1, s2):
    """For worst-case component estimates at the budget edge, the composite
    relative error never exceeds the Table 2 bound."""
    h1 = mu1 * (1 + s1 * e1)
    h2 = mu2 * (1 + s2 * e2)
    rel = lambda est, tru: abs(est - tru) / abs(tru)
    assert rel(h1 * h2, mu1 * mu2) <= propagation.propagate_product(e1, e2) + 1e-9
    assert rel(h1 / h2, mu1 / mu2) <= propagation.propagate_division(e1, e2) + 1e-9
    assert rel(h1 + h2, mu1 + mu2) <= propagation.propagate_addition(e1, e2) + 1e-9


@settings(max_examples=100, deadline=None)
@given(e=st.floats(0.005, 0.5))
def test_split_budget_inverts_propagation(e):
    for kind, prop in (("product", propagation.propagate_product),
                       ("ratio", propagation.propagate_division)):
        ep = propagation.split_budget(kind, e)
        assert prop(ep, ep) <= e + 1e-9
    assert propagation.split_budget("sum", e) == e
    assert propagation.split_budget("add", e) == e


def test_combine_estimates():
    assert propagation.combine_estimates("ratio", 10.0, 4.0) == 2.5
    assert propagation.combine_estimates("product", 3.0, 4.0) == 12.0
    assert propagation.combine_estimates("add", 3.0, 4.0, (2.0, 1.0)) == 10.0
    assert math.isnan(propagation.combine_estimates("ratio", 1.0, 0.0))


# -- allocation -----------------------------------------------------------------

def test_allocation_boole_arithmetic():
    b = allocate(0.95, 10, 0.05)
    assert b.confidence == pytest.approx(1 - 0.05 / 10)
    assert b.delta1 == pytest.approx((1 - b.confidence) / 3)
    assert b.p_prime == pytest.approx(b.confidence + b.delta1 + b.delta2)
    assert b.p_prime < 1.0


def test_allocation_joint_probability_identity():
    """Boole: sum of per-channel failure budgets equals the total budget."""
    C, p = 7, 0.9
    b = allocate(p, C, 0.1)
    per_channel_failure = 1 - b.confidence
    assert C * per_channel_failure == pytest.approx(1 - p)


def test_allocation_custom_delta_split_validation():
    with pytest.raises(ValueError):
        allocate(0.95, 1, 0.05, delta_split=(0.04, 0.04))
    b = allocate(0.95, 1, 0.05, delta_split=(0.005, 0.04))
    assert b.p_prime == pytest.approx(0.95 + 0.045)


def test_allocation_rejects_bad_inputs():
    with pytest.raises(ValueError):
        allocate(0.95, 0, 0.05)
    with pytest.raises(ValueError):
        allocate(0.7, 3, 0.05, coverage_debit=0.3)
