"""Constant-hoisted executables + batched drain-group launches.

The tentpole invariants of the constant-generic compiled layer:

* sweeping predicate/expression constants over a fixed plan shape produces
  bit-identical answers to the eager baseline while costing exactly ONE
  physical compilation per shape (``Executor.compile_cache_info()``) — the
  constants ride as a runtime operand, not as compile keys;
* a drain group's batched final launches (``lax.map`` lanes) are
  bit-identical to the serial per-member dispatches;
* pilot SHARING stays sub-keyed on the full constant-bearing signature:
  constant-varied queries never share pilot statistics (selectivity shapes
  the §4 bounds), even though they share every compiled executable.
"""

import dataclasses as dc

import numpy as np
import pytest

from repro.api import Session, SessionConfig
from repro.core.taqa import structural_signature, template_signature
from repro.engine import logical as L
from repro.engine.datagen import tpch_catalog
from repro.engine.executor import Executor
from repro.engine.expr import And, Col
from repro.engine.physical import plan_constants, plan_template

BR = 64

SERIAL_CFG = SessionConfig(async_workers=0, share_pilots=False,
                           batch_finals=False, result_cache_size=0)
BATCH_CFG = SessionConfig(async_workers=0, share_pilots=True,
                          batch_finals=True, result_cache_size=0)


@pytest.fixture(scope="module")
def catalog():
    return tpch_catalog(6_000, BR, seed=0)


@pytest.fixture(scope="module")
def big_catalog():
    return tpch_catalog(200_000, 32, seed=0)


# -- shape factories: each sweep varies ONLY constants ------------------------

def _q6_plan(lo, hi, cap):
    pred = And(Col("l_shipdate").between(lo, hi), Col("l_quantity") < cap)
    return L.Aggregate(
        child=L.Filter(L.Scan("lineitem"), pred),
        aggs=(L.AggSpec("sum", Col("l_extendedprice") * Col("l_discount"), "rev"),
              L.AggSpec("count", None, "cnt")))


def _grouped_plan(cut):
    return L.Aggregate(
        child=L.Filter(L.Scan("lineitem"), Col("l_shipdate") < cut),
        aggs=(L.AggSpec("sum", Col("l_quantity"), "qty"),
              L.AggSpec("count", None, "cnt")),
        group_by="l_returnflag", max_groups=3)


def _join_plan(cut):
    return L.Aggregate(
        child=L.Filter(L.Join(L.Scan("lineitem"), L.Scan("orders"),
                              "l_orderkey", "o_orderkey"),
                       Col("o_orderdate") < cut),
        aggs=(L.AggSpec("sum", Col("l_extendedprice"), "rev"),))


SWEEPS = {
    "q6": [_q6_plan(100 + 50 * i, 1500 + 30 * i, 20 + i) for i in range(6)],
    "grouped": [_grouped_plan(400 * (i + 1)) for i in range(6)],
    "join": [_join_plan(300 * (i + 1)) for i in range(6)],
}


# -- template extraction ------------------------------------------------------

def test_templates_unify_constant_variants():
    for name, plans in SWEEPS.items():
        templates = {plan_template(p) for p in plans}
        assert len(templates) == 1, name
        consts = [tuple(plan_constants(p).tolist()) for p in plans]
        assert len(set(consts)) == len(plans), name  # vectors stay distinct
        lengths = {len(c) for c in consts}
        assert len(lengths) == 1, name  # position-aligned slots


# -- property sweep: bit-identity + one compile miss per shape ----------------

@pytest.mark.parametrize("shape", list(SWEEPS))
def test_constant_sweep_one_compile_bit_identical(catalog, shape):
    compiled = Executor(catalog)
    eager = Executor(catalog, use_compiled=False)
    for i, plan in enumerate(SWEEPS[shape]):
        sampled = L.rewrite_scans(
            plan, {"lineitem": L.SampleClause("block", 0.3, seed=7 + i)})
        rc = compiled.execute(sampled)
        re = eager.execute(sampled)
        np.testing.assert_array_equal(rc.values, re.values)
        np.testing.assert_array_equal(rc.group_counts, re.group_counts)
        assert rc.scanned_bytes == re.scanned_bytes
    info = compiled.compile_cache_info()
    assert info.misses == 1, (shape, info)  # ONE executable for the sweep
    assert info.hits == len(SWEEPS[shape]) - 1


@pytest.mark.parametrize("shape", ["q6", "grouped"])
def test_pilot_constant_sweep_one_compile(catalog, shape):
    compiled = Executor(catalog)
    eager = Executor(catalog, use_compiled=False)
    for plan in SWEEPS[shape]:
        sc = compiled.execute_pilot(plan, "lineitem", 0.2, seed=3)
        se = eager.execute_pilot(plan, "lineitem", 0.2, seed=3)
        np.testing.assert_array_equal(sc.block_sums, se.block_sums)
        np.testing.assert_array_equal(sc.group_present, se.group_present)
    assert compiled.compile_cache_info().misses == 1


def test_pallas_kernel_route_shares_compilation_across_constants(catalog):
    """The Pallas filtered_agg route takes bounds by scalar prefetch: a
    constant sweep stays one kernel compilation and matches the XLA twin."""
    pallas = Executor(catalog, kernel_mode="pallas")
    xla = Executor(catalog)
    for plan in SWEEPS["q6"]:
        sp = pallas.execute_pilot(plan, "lineitem", 0.3, seed=5)
        sx = xla.execute_pilot(plan, "lineitem", 0.3, seed=5)
        np.testing.assert_allclose(sp.block_sums, sx.block_sums,
                                   rtol=1e-4, atol=1e-4)
    assert pallas.compile_cache_info().misses == 1
    routes = {c.route for c in pallas.physical._cache.values()}
    assert routes == {"pallas_filtered"}


# -- batched drain groups -----------------------------------------------------

def _herd_sqls():
    # constant-varied dashboard herd (one template, six constant sets) plus
    # spec-varied members of one constant set
    sqls = [(f"SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
             f"WHERE l_quantity < {c} ERROR 8% CONFIDENCE 95%")
            for c in (18, 21, 24, 27, 30, 33)]
    sqls.append("SELECT SUM(l_extendedprice * l_discount) AS rev FROM "
                "lineitem WHERE l_quantity < 24 ERROR 5% CONFIDENCE 95%")
    return sqls


def test_batched_drain_bit_identical_to_serial(big_catalog):
    serial = Session(big_catalog, seed=21, config=SERIAL_CFG)
    solo = {s: serial.sql(s) for s in _herd_sqls()}
    assert all(h.status == "done" for h in solo.values())

    batched = Session(big_catalog, seed=21, config=BATCH_CFG)
    handles = [batched.submit(s) for s in _herd_sqls()]
    stats_groups = None
    done = batched.drain()
    assert all(h.status == "done" for h in done)
    stats_groups = batched.scheduler.last_drain.n_groups
    # ONE template group: the constant-varied herd drains together
    assert stats_groups == 1
    for h in handles:
        assert np.array_equal(h.result().values, solo[h.sql].result().values)
    batched.close(), serial.close()


def test_constant_varied_herd_never_shares_pilots(big_catalog):
    """Template grouping widens the drain group, but pilot sharing must
    stay keyed on the constant-bearing signature: N distinct constants run
    N pilot stages (selectivity shapes the §4 bounds)."""
    session = Session(big_catalog, seed=9, config=BATCH_CFG)
    sqls = _herd_sqls()
    handles = [session.submit(s) for s in sqls]
    p0 = session.executor.pilots_run
    session.drain()
    distinct_constants = 6  # the ERROR 5% member shares the c=24 pilot
    assert session.executor.pilots_run - p0 == distinct_constants
    assert all(h.status == "done" for h in handles)
    # the spec-varied member reused the c=24 pilot
    shared = [h for h in handles if h.report is not None
              and h.report.pilot_shared]
    assert len(shared) == 1 and "ERROR 5%" in shared[0].sql
    session.close()


def test_group_key_strips_constants_signature_keeps_them(big_catalog):
    session = Session(big_catalog, seed=0, config=BATCH_CFG)
    h1 = session.prepare("SELECT COUNT(*) AS n FROM lineitem "
                         "WHERE l_quantity < 10 ERROR 9% CONFIDENCE 95%")
    h2 = session.prepare("SELECT COUNT(*) AS n FROM lineitem "
                         "WHERE l_quantity < 40 ERROR 9% CONFIDENCE 95%")
    assert h1.group_key == h2.group_key == template_signature(h1.query)
    assert h1.signature != h2.signature
    assert h1.signature == structural_signature(h1.query)
    session.close()


def test_executor_execute_batch_matches_solo(catalog):
    """The batched executable's lanes are bit-identical to solo dispatches,
    across constant variants sharing one bucket."""
    ex_batch = Executor(catalog)
    ex_solo = Executor(catalog)

    def plans_of(n):
        return [L.rewrite_scans(
            _q6_plan(100 + 10 * i, 1600, 20 + i),
            {"lineitem": L.SampleClause("block", 0.3, seed=i)})
            for i in range(n)]

    outs = ex_batch.execute_batch(plans_of(4))
    for plan, out in zip(plans_of(4), outs):
        ref = ex_solo.execute(plan)
        np.testing.assert_array_equal(out.values, ref.values)
        np.testing.assert_array_equal(out.group_counts, ref.group_counts)
        assert out.scanned_bytes == ref.scanned_bytes
    # one batch-of-4 compilation for the whole pow2-sized set
    assert ex_batch.compile_cache_info().misses == 1
    assert ex_batch.queries_run == 4

    # non-pow2 sets chunk greedily (5 -> 4+1): the 4-lane executable is
    # reused, the remainder runs solo — no padded (wasted) lanes ever
    m0 = ex_batch.compile_cache_info().misses
    outs5 = ex_batch.execute_batch(plans_of(5))
    for plan, out in zip(plans_of(5), outs5):
        np.testing.assert_array_equal(out.values, ex_solo.execute(plan).values)
    assert ex_batch.compile_cache_info().misses - m0 == 1  # the solo shape
    assert ex_batch.queries_run == 9


def _plain_plan(seedless_tag):
    # no filter chain: routes to block_agg (the no-predicate kernel); the
    # tag keeps the sweep's plans distinct without changing the template
    return L.Aggregate(
        child=L.Scan("lineitem"),
        aggs=(L.AggSpec("sum", Col("l_extendedprice"), "rev"),
              L.AggSpec("count", None, "cnt")))


@pytest.mark.parametrize("shape,route", [
    ("filtered", "pallas_filtered_batched"),
    ("block", "pallas_block_batched"),
])
def test_pallas_batched_lanes_bitwise_match_solo(catalog, shape, route):
    """Interpret-mode pinning of the batched kernel grid: every lane of the
    megacore-style batched filtered_agg/block_agg launch is BITWISE the
    member's solo kernel run — same per-block partials, same f32 reduction
    order — and the whole pow2 set costs ONE batched kernel compilation."""
    ex_batch = Executor(catalog, kernel_mode="pallas")
    ex_solo = Executor(catalog, kernel_mode="pallas")

    def make(i):
        base = (_q6_plan(100 + 10 * i, 1600, 20 + i) if shape == "filtered"
                else _plain_plan(i))
        return L.rewrite_scans(
            base, {"lineitem": L.SampleClause("block", 0.3, seed=i)})

    plans = [make(i) for i in range(4)]
    outs = ex_batch.execute_batch(plans)
    for plan, out in zip(plans, outs):
        ref = ex_solo.execute(plan)
        np.testing.assert_array_equal(out.values, ref.values)
        np.testing.assert_array_equal(out.raw_sums, ref.raw_sums)
        np.testing.assert_array_equal(out.group_counts, ref.group_counts)
        assert out.scanned_bytes == ref.scanned_bytes
    info = ex_batch.compile_cache_info()
    assert info.misses == info.batched_misses == 1, info
    routes = {c.route for c in ex_batch.physical._cache.values()}
    assert routes == {route}


def test_execute_batch_surfaces_empty_samples_per_member(catalog):
    ex = Executor(catalog)
    good = L.rewrite_scans(_q6_plan(100, 1500, 24),
                           {"lineitem": L.SampleClause("block", 0.4, seed=1)})
    empty = L.rewrite_scans(_q6_plan(100, 1500, 24),
                            {"lineitem": L.SampleClause("block", 1e-9, seed=1)})
    from repro.engine.executor import EmptySampleError
    outs = ex.execute_batch([good, empty, good])
    assert isinstance(outs[1], EmptySampleError)
    ref = Executor(catalog).execute(good)
    np.testing.assert_array_equal(outs[0].values, ref.values)
    np.testing.assert_array_equal(outs[2].values, ref.values)


def test_batching_respects_runtime_feature_toggles(big_catalog):
    """batch_finals=False keeps per-member dispatches; answers stay
    bit-identical either way (the invariant every toggle must keep)."""
    sqls = _herd_sqls()[:3]
    on = Session(big_catalog, seed=4, config=BATCH_CFG)
    off = Session(big_catalog, seed=4, config=dc.replace(BATCH_CFG,
                                                         batch_finals=False))
    h_on = [on.submit(s) for s in sqls]
    h_off = [off.submit(s) for s in sqls]
    on.drain(), off.drain()
    for a, b in zip(h_on, h_off):
        assert np.array_equal(a.result().values, b.result().values)
    on.close(), off.close()
