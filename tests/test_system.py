"""End-to-end behaviour of the full system: PilotDB middleware + LM runtime.

These are the cross-cutting scenarios a deployment exercises: the two-stage
AQP lifecycle (guarantee semantics under repeated use), the train->checkpoint
->restart->eval loop, and the technique-integration path (AQP-planned data
mixture feeding training).
"""

import numpy as np
import pytest

from repro.core import CompositeAgg, ErrorSpec, PilotDB, Query
from repro.engine import logical as L
from repro.engine.datagen import tpch_catalog
from repro.engine.executor import Executor
from repro.engine.expr import And, Col


@pytest.fixture(scope="module")
def db():
    cat = tpch_catalog(scale_rows=600_000, block_rows=32, seed=0)
    return PilotDB(Executor(cat), large_table_rows=50_000)


def test_middleware_lifecycle_repeated_queries(db):
    """Same middleware instance, many queries: guarantees hold, shape-bucket
    caches make later queries cheap, fallbacks never lie."""
    spec = ErrorSpec(error=0.08, confidence=0.9)
    q = Query(child=L.Filter(L.Scan("lineitem"),
                             And(Col("l_shipdate").between(100, 1500),
                                 Col("l_discount").between(0.02, 0.08))),
              aggs=(CompositeAgg("rev", "sum",
                                 Col("l_extendedprice") * Col("l_discount")),))
    exact = db.exact(q)
    errs, scan_fracs = [], []
    for seed in range(6):
        ans = db.query(q, spec, seed=seed)
        assert ans.report.fallback is None
        errs.append(abs(ans.scalar("rev") - exact.scalar("rev"))
                    / exact.scalar("rev"))
        scan_fracs.append((ans.report.pilot_scanned_bytes
                           + ans.report.final_scanned_bytes)
                          / ans.report.exact_scanned_bytes)
    assert max(errs) <= spec.error
    assert np.mean(scan_fracs) < 0.35


def test_error_spec_is_a_priori_not_post_hoc(db):
    """The plan is decided before the final query runs (structural check:
    plan rates depend only on the pilot, so same seed => same plan)."""
    spec = ErrorSpec(error=0.08, confidence=0.9)
    q = Query(child=L.Scan("lineitem"),
              aggs=(CompositeAgg("qty", "sum", Col("l_quantity")),))
    a1 = db.query(q, spec, seed=42)
    a2 = db.query(q, spec, seed=42)  # same seed -> same pilot -> same plan
    assert a1.report.plan.rates == a2.report.plan.rates


def test_train_checkpoint_restart_eval_loop(tmp_path):
    """The full production loop on a reduced model."""
    from repro.launch.train import main as train_main

    ck = str(tmp_path / "ck")
    losses1 = train_main(["--arch", "granite-moe-1b-a400m", "--reduced",
                          "--steps", "12", "--batch", "4", "--seq", "32",
                          "--ckpt-dir", ck, "--ckpt-every", "6"])
    losses2 = train_main(["--arch", "granite-moe-1b-a400m", "--reduced",
                          "--steps", "16", "--batch", "4", "--seq", "32",
                          "--ckpt-dir", ck, "--resume"])
    assert len(losses2) == 4  # resumed from step 12, ran 12..15
    assert np.isfinite(losses1 + losses2).all()


def test_serve_engine_cross_arch():
    import jax

    from repro.configs import ARCHITECTURES
    from repro.models import build_model
    from repro.serve.engine import ServeEngine

    for arch in ("hymba-1.5b", "granite-moe-1b-a400m"):
        cfg = ARCHITECTURES[arch].reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, batch_slots=2, cache_len=32)
        ids = [eng.submit([1, 2], max_new_tokens=4) for _ in range(3)]
        out = eng.run()
        assert set(out) == set(ids)
        assert all(len(v) == 4 for v in out.values())


def test_aqp_technique_integration_into_training():
    """The paper's technique drives the data layer: mixture weights come from
    a guaranteed-error grouped AVG over corpus metadata."""
    from repro.train.data import TokenPipeline, make_domain_metadata, plan_mixture_weights

    meta = make_domain_metadata({"a": 1500, "b": 1500}, block_rows=64, seed=3)
    weights, report = plan_mixture_weights(meta, 2, error=0.1, confidence=0.9)
    assert report.fallback is None
    scanned = report.pilot_scanned_bytes + report.final_scanned_bytes
    assert scanned < 0.5 * report.exact_scanned_bytes  # genuinely approximate
    pipe = TokenPipeline(512, batch=4, seq=8,
                         domains={"a": weights[0], "b": weights[1]})
    assert pipe.next_batch()["tokens"].shape == (4, 8)
