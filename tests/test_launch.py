"""Launch substrate: HLO analyzer, shape specs, sharding rules, mesh plans.

The 512-device dry-run itself runs as its own process (it must set XLA_FLAGS
before jax init); here we unit-test its building blocks on 1 device plus a
synthetic HLO covering the loop/collective/DUS accounting rules.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.specs import SHAPES, batch_specs, cell_supported, input_specs
from repro.train.sharding import param_pspec
from jax.sharding import Mesh, PartitionSpec as P


SYNTH_HLO = """
HloModule jit_f, num_partitions=4

%body (param: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %param = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%param), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%param), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,64]{1,0} all-gather(%dot.1), channel_id=1, replica_groups=[1,4]<=[4], dimensions={1}
  %one = s32[] constant(1)
  %next = s32[] add(%i, %one)
  ROOT %tuple = (s32[], f32[8,16]{1,0}) tuple(%next, %dot.1)
}

%cond (param.1: (s32[], f32[8,16])) -> pred[] {
  %param.1 = (s32[], f32[8,16]{1,0}) parameter(0)
  %i.1 = s32[] get-tuple-element(%param.1), index=0
  %trip = s32[] constant(5)
  ROOT %lt = pred[] compare(%i.1, %trip), direction=LT
}

ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
  %arg = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]{1,0}) tuple(%zero, %arg)
  %loop = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond, body=%body
  %buf = f32[40,16]{1,0} constant({...})
  %upd = f32[1,16]{1,0} constant({...})
  %idx = s32[] constant(0)
  %dus = f32[40,16]{1,0} dynamic-update-slice(%buf, %upd, %idx, %idx)
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_synthetic_hlo_loop_and_collective_accounting():
    res = analyze_hlo(SYNTH_HLO)
    # dot: 2*8*16*16 flops, x5 loop trips
    assert res["flops_per_device"] == pytest.approx(5 * 2 * 8 * 16 * 16)
    # all-gather: out 8*64*4 bytes * (4-1)/4, x5 trips
    assert res["collective_bytes_per_device"] == pytest.approx(
        5 * 8 * 64 * 4 * 0.75)
    assert res["collective_counts"]["all-gather"] == 5
    assert res["entry"].startswith("main")
    # DUS counts only the update slice (1*16*4 bytes), not the 40x16 buffer.
    # Per loop iter: dot (512*2 + 1024) + all-gather in+out (512 + 2048)
    # + scalars = ~4620 bytes; x5 + the DUS update ~= 23.2 kB — crucially
    # NOT the 40x16 buffer per iteration (that's the ~20x inflation the
    # in-place rule prevents).
    assert 20_000 < res["hbm_bytes_per_device"] < 26_000


def test_analyzer_on_real_compiled_module():
    @jax.jit
    def f(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    w = jnp.zeros((3, 32, 32))
    x = jnp.zeros((8, 32))
    compiled = f.lower(w, x).compile()
    res = analyze_hlo(compiled.as_text())
    assert res["flops_per_device"] == pytest.approx(3 * 2 * 8 * 32 * 32, rel=0.01)
    assert res["collective_bytes_per_device"] == 0.0  # single device


# -- shape specs ---------------------------------------------------------------

def test_shapes_cover_assignment():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1


def test_long_500k_applicability_matches_design():
    runnable = {a for a in ARCHITECTURES
                if cell_supported(ARCHITECTURES[a], SHAPES["long_500k"])[0]}
    assert runnable == {"rwkv6-7b", "hymba-1.5b"}


@pytest.mark.parametrize("arch", sorted(ARCHITECTURES))
def test_input_specs_are_abstract_and_complete(arch):
    cfg = ARCHITECTURES[arch]
    for shape_name, shape in SHAPES.items():
        if not cell_supported(cfg, shape)[0]:
            continue
        specs = input_specs(cfg, shape_name)
        leaves = jax.tree.leaves(specs)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        if shape.kind == "train":
            b = specs["batch"]
            assert b["tokens"].shape[0] == shape.global_batch
            assert "labels" in b
            if cfg.family == "vlm":
                assert b["tokens"].shape[1] + cfg.num_patches == shape.seq_len
        else:
            if shape.kind == "decode":
                assert specs["token"].shape == (shape.global_batch,)
                assert specs["cache"]["pos"].shape == (shape.global_batch,)


def test_decode_cache_is_bounded_for_subquadratic():
    hymba = ARCHITECTURES["hymba-1.5b"]
    specs = input_specs(hymba, "long_500k")
    k = specs["cache"]["k"]
    assert k.shape[3] == hymba.sliding_window  # ring buffer, not 524288


# -- sharding rules ---------------------------------------------------------------

def test_param_pspec_rules_single_device_mesh():
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # nothing divides a 1x1 mesh... everything still legal (replicated)
    assert param_pspec("layers/wq", (24, 2048, 2048), mesh) == P(None, ("data",), "model")


def test_param_pspec_divisibility_guard():
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # odd dims fall back to replication rather than invalid shardings
    spec = param_pspec("layers/wk", (24, 2047, 129), mesh)
    assert spec == P(None, ("data",), "model")  # 1x1 divides everything


def test_vocab_padding_divisible_by_tp():
    from repro.models.model import padded_vocab

    for cfg in ARCHITECTURES.values():
        assert padded_vocab(cfg) % 16 == 0  # TP=16 always divides
