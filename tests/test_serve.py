"""Serving engine + SQL gateway + guaranteed approximate evaluation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Session
from repro.aqpeval import GuaranteedEvaluator
from repro.configs import ARCHITECTURES
from repro.engine.datagen import tpch_catalog
from repro.models import build_model
from repro.serve.engine import ServeEngine
from repro.serve.sql_gateway import SqlGateway

RNG = jax.random.PRNGKey(0)


def make_engine(arch="internlm2-1.8b", slots=3, cache_len=64):
    cfg = ARCHITECTURES[arch].reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    return ServeEngine(model, params, batch_slots=slots, cache_len=cache_len)


def test_engine_serves_batched_requests():
    eng = make_engine()
    ids = [eng.submit([1, 2, 3], max_new_tokens=5) for _ in range(5)]
    out = eng.run()
    assert set(out) == set(ids)
    assert all(len(v) == 5 for v in out.values())
    v = eng.model.cfg.vocab_size
    assert all(0 <= t < v for toks in out.values() for t in toks)


def test_engine_continuous_batching_isolation():
    """A request admitted into a reused slot must match a fresh engine's
    output for the same prompt (no state leakage across requests)."""
    eng = make_engine(slots=1)
    eng.submit([5, 6, 7], max_new_tokens=4)
    eng.submit([9, 8], max_new_tokens=4)  # reuses slot 0 afterwards
    out = eng.run()
    fresh = make_engine(slots=1)
    fresh.submit([9, 8], max_new_tokens=4)
    expected = fresh.run()
    assert out[1] == expected[0]


def test_engine_ssm_arch_state_reset():
    eng = make_engine("rwkv6-7b", slots=2)
    a = eng.submit([3, 3, 3], max_new_tokens=3)
    out1 = eng.run()
    b = eng.submit([3, 3, 3], max_new_tokens=3)
    out2 = eng.run()
    assert out1[a] == out2[b]  # identical prompt -> identical greedy output


def test_engine_single_compiled_graph():
    eng = make_engine(slots=2)
    eng.submit([1], max_new_tokens=3)
    eng.run()
    n1 = eng._decode._cache_size()
    eng.submit([2, 3], max_new_tokens=3)
    eng.run()
    assert eng._decode._cache_size() == n1  # no recompilation


# -- SQL gateway: the AQP serving front -------------------------------------------

@pytest.fixture(scope="module")
def aqp_session():
    return Session(tpch_catalog(scale_rows=200_000, block_rows=32, seed=0),
                   seed=5)


def test_gateway_serves_many_clients_warm(aqp_session):
    """A herd of identical dashboard queries from different clients runs as
    one signature group: ONE pilot stage, one final, and every other ticket
    answered from the session result cache with the original report."""
    gw = SqlGateway(aqp_session)
    sql = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
           "WHERE l_quantity < 24 ERROR 10% CONFIDENCE 90%")
    tickets = {gw.submit(f"client{i}", sql): f"client{i}" for i in range(8)}
    assert len(gw.results_for("client3")) == 1  # queued, not yet delivered
    results = gw.run()
    assert set(results) == set(tickets)
    assert all(h.status == "done" for h in results.values())
    assert gw.stats.served == 8 and gw.stats.rejected == 0
    # serving-scale amortization: 8 requests, one pilot stage, the herd's
    # tail answered from the result cache, bit-identical answers throughout
    assert gw.stats.pilots_run == 1
    assert gw.stats.result_hits == 7
    vals = {h.scalar("rev") for h in results.values()}
    assert len(vals) == 1
    # delivered tickets are pruned: no re-delivery, no unbounded growth
    assert gw.results_for("client3") == []
    assert gw.run() == {}
    # the SAME dashboard re-issued later answers entirely from cache
    t2 = gw.submit("client0", sql)
    out2 = gw.run()
    assert out2[t2].cached
    assert out2[t2].scalar("rev") in vals


def test_gateway_bad_sql_fails_only_that_ticket(aqp_session):
    gw = SqlGateway(aqp_session)
    good = gw.submit("alice", "SELECT COUNT(*) AS n FROM lineitem")
    bad = gw.submit("bob", "SELEKT COUNT(*) FROM lineitem")
    missing = gw.submit("eve", "SELECT COUNT(*) AS n FROM not_a_table "
                               "GROUP BY g")
    out_of_range = gw.submit("mallory", "SELECT COUNT(*) AS n FROM lineitem "
                                        "ERROR 150% CONFIDENCE 95%")
    deep = gw.submit("trudy", "SELECT COUNT(*) AS n FROM lineitem WHERE "
                     + " AND ".join(["l_quantity < 24"] * 2000))
    results = gw.run()
    assert results[good].status == "done"
    assert results[bad].status == "failed"
    assert "SqlSyntaxError" in results[bad].error
    assert results[missing].status == "failed"
    assert results[out_of_range].status == "failed"
    # a parser-depth-busting request fails its own ticket, not the batch
    assert results[deep].status == "failed"
    assert gw.stats.rejected >= 2
    assert gw.stats.requests == 5


def test_gateway_rejects_degenerate_batch_size(aqp_session):
    with pytest.raises(ValueError):
        SqlGateway(aqp_session, batch_size=0)


def test_gateway_batched_drains(aqp_session):
    gw = SqlGateway(aqp_session, batch_size=3)
    sql = "SELECT SUM(l_quantity) AS q FROM lineitem ERROR 10% CONFIDENCE 90%"
    for i in range(7):
        gw.submit(f"c{i}", sql)
    results = gw.run()
    assert len(results) == 7
    assert gw.stats.drains >= 3  # 3 + 3 + 1 under batch_size=3


def test_gateway_backpressure_bounded_admission(aqp_session):
    from repro.api import BackpressureError
    gw = SqlGateway(aqp_session, max_pending=3)
    sql = "SELECT COUNT(*) AS n FROM lineitem"
    for i in range(3):
        gw.submit(f"c{i}", sql)
    with pytest.raises(BackpressureError, match="admission queue full"):
        gw.submit("c3", sql)
    assert gw.stats.throttled == 1
    # a throttled request never became a ticket nor a request
    assert gw.stats.requests == 3
    # draining frees admission capacity
    assert len(gw.run()) == 3
    t = gw.submit("c3", sql)
    assert gw.run()[t].status == "done"


def test_gateway_admission_budget_isolated_per_gateway(aqp_session):
    """One gateway's queued work must not consume another's max_pending."""
    from repro.api import BackpressureError
    gw1 = SqlGateway(aqp_session)
    gw2 = SqlGateway(aqp_session, max_pending=1)
    gw1.submit("a", "SELECT COUNT(*) AS n FROM orders")
    gw1.submit("a", "SELECT COUNT(*) AS n FROM lineitem")
    t = gw2.submit("b", "SELECT COUNT(*) AS n FROM orders")
    with pytest.raises(BackpressureError):
        gw2.submit("b", "SELECT COUNT(*) AS n FROM lineitem")
    gw1.run()
    assert gw2.run()[t].status == "done"


def test_gateway_backpressure_per_client_cap(aqp_session):
    from repro.api import BackpressureError
    gw = SqlGateway(aqp_session, max_inflight_per_client=2)
    sql = "SELECT COUNT(*) AS n FROM lineitem"
    gw.submit("greedy", sql)
    gw.submit("greedy", sql)
    with pytest.raises(BackpressureError, match="greedy"):
        gw.submit("greedy", sql)
    # the cap is per client: others are unaffected by the greedy one
    t = gw.submit("polite", sql)
    results = gw.run()
    assert t in results and gw.stats.throttled == 1


def test_gateway_stats_payload_one_stop(aqp_session):
    """stats_payload() surfaces the gateway counters, the physical
    compile-cache counters, and the result-cache hit/byte counters in one
    payload — no reaching into session internals."""
    gw = SqlGateway(aqp_session)
    sql = ("SELECT SUM(l_quantity) AS q FROM lineitem "
           "WHERE l_quantity < 30 ERROR 10% CONFIDENCE 90%")
    for i in range(3):
        gw.submit(f"c{i}", sql)
    gw.run()
    payload = gw.stats_payload()
    assert payload["gateway"]["requests"] == gw.stats.requests == 3
    assert payload["gateway"]["served"] == 3
    info = aqp_session.compile_cache_info()
    assert payload["compile_cache"] == {
        "hits": info.hits, "misses": info.misses, "size": info.size,
        "staged_hits": info.staged_hits, "staged_misses": info.staged_misses,
        "pilot_hits": info.pilot_hits, "pilot_misses": info.pilot_misses,
        "batched_hits": info.batched_hits,
        "batched_misses": info.batched_misses,
        "fused_hits": info.fused_hits, "fused_misses": info.fused_misses,
        "shared_hits": info.shared_hits}
    rc = aqp_session.result_cache_info()
    assert payload["result_cache"]["hits"] == rc.hits >= 2
    assert payload["result_cache"]["bytes_used"] == rc.bytes_used > 0
    assert payload["result_cache"]["capacity"] == rc.capacity
    # nothing sharded on this session: the dist section is present but empty
    assert payload["shard_scanned_bytes"] == {}
    # no staged_rates registration: the staged section reports zero state —
    # with the FULL key schema pinned (consumers must never key-check)
    assert set(payload["staged"]) == {"hits", "misses", "evictions",
                                      "resident_bytes", "max_bytes",
                                      "tables"}
    assert payload["staged"]["hits"] == 0
    assert payload["staged"]["tables"] == {}
    # the payload's top-level sections are a pinned contract too
    assert set(payload) == {"gateway", "compile_cache", "result_cache",
                            "shard_scanned_bytes", "staged", "runtime",
                            "audit", "timeseries", "slo"}
    # telemetry off: the sections are present with zero state
    assert payload["timeseries"]["enabled"] is False
    assert payload["timeseries"]["templates"] == {}
    assert payload["slo"]["enabled"] is False
    # streaming counters ride the gateway section
    assert {"streams", "frames_pushed",
            "frames_dropped"} <= set(payload["gateway"])


# The full stats_payload() schema, every key documented in
# SqlGateway.stats_payload's docstring.  SCHEMA-STABILITY CONTRACT: keys are
# additive-only — extend these sets when adding a metric, never remove or
# retype an existing key (dashboards key into this payload).
_PAYLOAD_SCHEMA = {
    "gateway": {"requests", "rejected", "throttled", "served", "drains",
                "compile_misses", "compile_hits", "pilots_run",
                "result_hits", "streams", "frames_pushed", "frames_dropped",
                "cache_hit_rate"},
    "compile_cache": {"hits", "misses", "size", "staged_hits",
                      "staged_misses", "pilot_hits", "pilot_misses",
                      "batched_hits", "batched_misses", "fused_hits",
                      "fused_misses", "shared_hits"},
    "result_cache": {"hits", "misses", "evictions", "invalidations", "size",
                     "capacity", "bytes_used", "max_bytes", "hit_rate"},
    "shard_scanned_bytes": None,   # dict of table -> per-shard byte lists
    "staged": {"hits", "misses", "evictions", "resident_bytes", "max_bytes",
               "tables"},
    "runtime": {"queries_run", "pilots_run", "workers", "pilot_workers",
                "in_flight", "groups_total", "pilot_fanouts",
                "pilot_fanout_wall_s", "pilot_fanout_serial_s"},
    "audit": {"runs", "violations", "errors", "max_error_ratio"},
    "timeseries": {"enabled", "window", "drains", "ttff_s", "ttf_s",
                   "templates"},
    "slo": {"enabled", "targets", "breaches_total", "evaluations_total",
            "recent_breaches"},
}


def test_gateway_stats_payload_schema_stable(aqp_session):
    """Satellite contract: the payload schema is pinned recursively — every
    documented section and key is present (with numeric leaves JSON-able)
    on a warm gateway, so payload consumers never key-check."""
    import json
    gw = SqlGateway(aqp_session)
    gw.submit("c0", "SELECT SUM(l_quantity) AS q FROM lineitem "
                    "WHERE l_quantity < 30 ERROR 10% CONFIDENCE 90%")
    gw.run()
    payload = gw.stats_payload()
    assert set(payload) == set(_PAYLOAD_SCHEMA)
    for section, keys in _PAYLOAD_SCHEMA.items():
        assert isinstance(payload[section], dict)
        if keys is not None:
            assert keys <= set(payload[section]), \
                f"{section} lost keys: {keys - set(payload[section])}"
    json.dumps(payload)  # the whole payload serves over the wire as-is
    # the payload is a view over the metrics registry: same numbers
    tree = aqp_session.metrics.tree()
    assert payload["compile_cache"] == tree["compile_cache"]
    assert payload["result_cache"] == tree["result_cache"]
    assert payload["runtime"] == tree["runtime"]


def test_gateway_metrics_text_prometheus(aqp_session):
    """metrics_text() renders the session registry — gateway counters
    included — in Prometheus text exposition format."""
    gw = SqlGateway(aqp_session)
    gw.submit("c0", "SELECT COUNT(*) AS n FROM lineitem")
    gw.run()
    text = gw.metrics_text()
    assert text.endswith("\n")
    for line in text.splitlines():
        assert line.startswith("#") or len(line.split()) == 2
    assert f"{gw._collector_name}_served 1" in text
    assert "compile_cache_hits" in text


def test_gateway_stats_payload_shard_attribution():
    """With a partitioned registration the payload carries per-shard
    sampled-slab bytes that sum to the monolithic attribution."""
    from repro.api import SessionConfig
    session = Session(seed=5, config=SessionConfig(large_table_rows=10_000))
    cat = tpch_catalog(scale_rows=24_000, block_rows=64, seed=0)
    session.register_table("lineitem", cat["lineitem"], shards=3)
    gw = SqlGateway(session)
    gw.submit("c0", "SELECT SUM(l_quantity) AS q FROM lineitem "
                    "WHERE l_quantity < 30 ERROR 8% CONFIDENCE 90%")
    gw.run()
    per_shard = gw.stats_payload()["shard_scanned_bytes"]["lineitem"]
    assert len(per_shard) == 3 and sum(per_shard) > 0
    expected = session.executor.shard_scan_info()["lineitem"]
    assert per_shard == list(expected)
    session.close()


# -- guaranteed approximate evaluation -------------------------------------------

def test_guaranteed_eval_bounds_error():
    rng = np.random.default_rng(0)
    n_blocks, per_block = 2000, 32
    losses = rng.gamma(2.0, 1.5, (n_blocks, per_block))
    true_mean = losses.mean()

    def block_metric(ids):
        sel = losses[ids]
        return sel.sum(axis=1), np.full(len(ids), per_block, float)

    viol = 0
    trials = 20
    for s in range(trials):
        ev = GuaranteedEvaluator(n_blocks, block_metric, seed=s)
        res = ev.evaluate(error=0.05, confidence=0.9)
        assert not res.exact
        rel = abs(res.estimate - true_mean) / true_mean
        viol += rel > 0.05
        assert res.blocks_saved_frac > 0.3  # actually cheaper than full eval
    assert viol <= 2  # 90% confidence, 20 trials


def test_guaranteed_eval_exact_fallback():
    """Impossible tolerance at the rate cap -> exact evaluation, not a lie."""
    rng = np.random.default_rng(1)
    losses = rng.gamma(2.0, 1.5, (40, 4))  # far too few blocks

    def block_metric(ids):
        sel = losses[ids]
        return sel.sum(axis=1), np.full(len(ids), 4, float)

    ev = GuaranteedEvaluator(40, block_metric, seed=0)
    res = ev.evaluate(error=0.001, confidence=0.99)
    assert res.exact
    assert res.estimate == pytest.approx(losses.mean())


def test_guaranteed_eval_with_real_model_loss():
    """End-to-end: approximate eval of a tiny LM over synthetic shards."""
    cfg = ARCHITECTURES["internlm2-1.8b"].reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    n_blocks, bsz, seq = 64, 2, 8
    rng = np.random.default_rng(2)
    shards = rng.integers(0, cfg.vocab_size, (n_blocks, bsz, seq + 1))

    @jax.jit
    def shard_loss(tokens):
        logits, _ = model.forward(params, {"tokens": tokens[:, :-1]})
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(lp, tokens[:, 1:, None], axis=-1)
        return nll.sum()

    def block_metric(ids):
        sums = np.array([float(shard_loss(jnp.asarray(shards[i]))) for i in ids])
        return sums, np.full(len(ids), bsz * seq, float)

    ev = GuaranteedEvaluator(n_blocks, block_metric, seed=3)
    res = ev.evaluate(error=0.05, confidence=0.9, pilot_blocks=12)
    full_sums, full_counts = block_metric(np.arange(n_blocks))
    truth = full_sums.sum() / full_counts.sum()
    assert abs(res.estimate - truth) / truth <= 0.05
