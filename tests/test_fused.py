"""Fused single-launch TAQA: bit-identity against the two-stage oracle.

The fused program (``physical.compile_fused``) runs pilot scan -> BSAP rate
solve -> final sampled aggregation as ONE device dispatch with no host sync
between the stages.  The two-stage path is the oracle: for every cell of the
matrix below — solo, constant-varied herd, cached re-issue, staged ladder,
1-shard and 2-shard registrations — ``fused_taqa=True`` must deliver answers
``np.array_equal`` to ``fused_taqa=False`` (same content-derived draws, same
f32/f64 reduction order).  Sharded cells pass trivially by construction: the
fused envelope gates sharded pilot tables off, so both sessions execute the
identical two-stage path there.
"""

import dataclasses as dc

import numpy as np
import pytest

from repro.api import Session, SessionConfig
from repro.core import CompositeAgg, ErrorSpec, PilotDB, Query
from repro.engine import logical as L
from repro.engine.datagen import tpch_catalog
from repro.engine.executor import Executor
from repro.engine.expr import And, Col

BASE = SessionConfig(async_workers=0)
FUSED = dc.replace(BASE, fused_taqa=True)

SQL = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
       "WHERE l_shipdate BETWEEN 100 AND 1500 "
       "AND l_discount BETWEEN 0.02 AND 0.08 AND l_quantity < 24 "
       "ERROR 8% CONFIDENCE 95%")
HERD = [SQL.replace("BETWEEN 100 AND 1500", f"BETWEEN 100 AND {1500 + 40 * i}")
        for i in range(4)]


@pytest.fixture(scope="module")
def catalog():
    return tpch_catalog(scale_rows=600_000, block_rows=32, seed=0)


def q6():
    pred = And(Col("l_shipdate").between(100, 1500),
               And(Col("l_discount").between(0.02, 0.08),
                   Col("l_quantity") < 24))
    return Query(child=L.Filter(L.Scan("lineitem"), pred),
                 aggs=(CompositeAgg("revenue", "sum",
                                    Col("l_extendedprice") * Col("l_discount")),))


def _run(catalog, cfg, sqls, *, shards=None, staged=None, sequential=False):
    s = Session(seed=11, config=cfg)
    for name, tab in catalog.items():
        s.register_table(name, tab,
                         shards=shards if name == "lineitem" else None,
                         staged_rates=staged if name == "lineitem" else None)
    if sequential:  # drain each query on its own (cached re-issue shape)
        handles = []
        for q in sqls:
            handles.append(s.submit(q))
            s.drain()
    else:
        handles = [s.submit(q) for q in sqls]
        s.drain()
    vals = []
    for h in handles:
        assert h.status == "done", h.error
        vals.append(h.result().values)
    info = s.compile_cache_info()
    s.close()
    return vals, info


MATRIX = {
    "solo": dict(sqls=[SQL]),
    "herd": dict(sqls=HERD),
    # sequential re-issue: the second drain answers from the result cache
    # (a fused-computed entry must rebuild the identical answer)
    "cached": dict(sqls=[SQL, SQL], sequential=True),
    "staged": dict(sqls=[SQL], staged=True),
    "shard1": dict(sqls=[SQL], shards=1),
    "shard2": dict(sqls=[SQL], shards=2),
}


@pytest.mark.parametrize("cell", list(MATRIX))
def test_fused_bitwise_matrix(catalog, cell):
    kw = dict(MATRIX[cell])
    sqls = kw.pop("sqls")
    base_vals, _ = _run(catalog, BASE, sqls, **kw)
    fused_vals, info = _run(catalog, FUSED, sqls, **kw)
    for a, b in zip(base_vals, fused_vals):
        np.testing.assert_array_equal(a, b)
    engaged = info.fused_hits + info.fused_misses
    if cell in ("shard1", "shard2"):
        # sharded pilot tables are outside the fused envelope: the fused
        # session must have executed the identical two-stage path
        assert engaged == 0, info
    else:
        assert engaged >= 1, info


def test_run_fused_is_one_dispatch_and_bitwise(catalog):
    """PilotDB-level pinning: the fused program answers in exactly ONE
    device dispatch (the two-stage oracle takes >= 2: pilot + final), with
    values, report statistics, and scanned-bytes attribution bitwise equal
    — across several seeds so the rate solve lands on different draws."""
    spec = ErrorSpec(error=0.08, confidence=0.95)
    for seed in range(4):
        ex_a, ex_b = Executor(catalog), Executor(catalog)
        db_a = PilotDB(ex_a, large_table_rows=50_000)
        db_b = PilotDB(ex_b, large_table_rows=50_000)
        ans_a = db_a.query(q6(), spec, seed=seed)
        ans_b = db_b.run_fused(q6(), spec, seed=seed)
        assert ans_b is not None, "fused path did not engage"
        assert ex_a.device_dispatches >= 2
        assert ex_b.device_dispatches == 1, (seed, ex_b.device_dispatches)
        np.testing.assert_array_equal(ans_a.values, ans_b.values)
        ra, rb = ans_a.report, ans_b.report
        assert ra.fallback == rb.fallback
        assert ra.theta_pilot == rb.theta_pilot
        assert ra.n_pilot_blocks == rb.n_pilot_blocks
        assert ra.pilot_scanned_bytes == rb.pilot_scanned_bytes
        assert ra.final_scanned_bytes == rb.final_scanned_bytes
        assert dict(ra.plan.rates) == dict(rb.plan.rates)


def test_run_fused_gates_to_none_outside_envelope(catalog):
    """Ineligible shapes return None BEFORE any device work, so the caller
    falls through to the two-stage path having executed nothing."""
    spec = ErrorSpec(error=0.08, confidence=0.95)
    ex = Executor(catalog)
    db = PilotDB(ex, large_table_rows=50_000)
    grouped = Query(child=L.Scan("lineitem"),
                    aggs=(CompositeAgg("qty", "sum", Col("l_quantity")),),
                    group_by="l_returnflag", max_groups=3)
    join = Query(child=L.Filter(
        L.Join(L.Scan("lineitem"), L.Scan("orders"),
               "l_orderkey", "o_orderkey"),
        Col("o_orderdate") < 1200),
        aggs=(CompositeAgg("rev", "sum", Col("l_extendedprice")),))
    assert db.run_fused(grouped, spec, seed=0) is None
    assert db.run_fused(join, spec, seed=0) is None
    assert ex.device_dispatches == 0
    assert ex.pilots_run == 0
    # eager executors never fuse
    db_eager = PilotDB(Executor(catalog, use_compiled=False),
                       large_table_rows=50_000)
    assert db_eager.run_fused(q6(), spec, seed=0) is None


def test_batched_pilots_bitwise_match_solo(catalog):
    """run_pilots_batched stacks same-shape pilot scans into one dispatch;
    every member's statistics must be bitwise the solo run_pilot's."""
    reqs = []
    for i in range(3):
        pred = And(Col("l_shipdate").between(100, 1500 + 40 * i),
                   And(Col("l_discount").between(0.02, 0.08),
                       Col("l_quantity") < 24))
        q = Query(child=L.Filter(L.Scan("lineitem"), pred),
                  aggs=(CompositeAgg("revenue", "sum",
                                     Col("l_extendedprice") * Col("l_discount")),))
        reqs.append((q, ErrorSpec(error=0.08, confidence=0.95), 1000 + i))
    ex_solo = Executor(catalog)
    db_solo = PilotDB(ex_solo, large_table_rows=50_000)
    solo = [db_solo.run_pilot(q, spec, psd) for q, spec, psd in reqs]
    d_solo = ex_solo.device_dispatches

    ex_b = Executor(catalog)
    db_b = PilotDB(ex_b, large_table_rows=50_000)
    batched = db_b.run_pilots_batched(reqs)
    assert ex_b.device_dispatches == 1 < d_solo == len(reqs)
    assert ex_b.pilots_run == len(reqs)
    for a, b in zip(solo, batched):
        assert not isinstance(b, Exception), b
        assert a.report.fallback == b.report.fallback
        np.testing.assert_array_equal(a.pilot.block_sums, b.pilot.block_sums)
        np.testing.assert_array_equal(a.pilot.group_present,
                                      b.pilot.group_present)
        assert a.pilot.theta_p == b.pilot.theta_p
        assert a.report.pilot_scanned_bytes == b.report.pilot_scanned_bytes
        assert a.report.n_pilot_blocks == b.report.n_pilot_blocks


def test_fused_session_matches_streaming_off_and_on(catalog):
    """fused_taqa composes with streaming: the terminal frame's answer is
    the same object result() returns, bitwise equal to the base session."""
    base, _ = _run(catalog, BASE, [SQL])
    s = Session(seed=11, config=FUSED)
    for name, tab in catalog.items():
        s.register_table(name, tab)
    h = s.submit(SQL)
    h.enable_streaming()
    s.drain()
    assert h.status == "done", h.error
    frames = h.frames()
    assert frames, "no terminal frame"
    np.testing.assert_array_equal(h.result().values, base[0])
    s.close()


def test_fused_audit_and_telemetry_interplay(catalog, tmp_path):
    """Satellite: fused_taqa + audit + telemetry compose — the fused
    delivery's provenance reports +fused (explain shows the engaged span),
    the audit checks the fused answer against an exact run, and the
    time-series counts the delivery as fused."""
    from repro.obs.audit import provenance_of

    base, _ = _run(catalog, BASE, [SQL])
    cfg = dc.replace(FUSED, tracing=True, audit=True, telemetry=True,
                     flight_recorder=str(tmp_path / "events.jsonl"))
    s = Session(seed=11, config=cfg)
    for name, tab in catalog.items():
        s.register_table(name, tab)
    h = s.submit(SQL)
    s.drain()
    assert h.status == "done", h.error
    # full observability changes no fused answer
    np.testing.assert_array_equal(h.result().values, base[0])
    fused_spans = h._trace.find("fused")
    assert fused_spans and fused_spans[0].attrs.get("engaged"), \
        "q6-shaped query should engage the fused program"
    assert provenance_of(h).endswith("+fused")
    assert "fused: engaged" in h.explain()
    rec = h.audit_record
    assert rec is not None and rec.skipped is None and rec.passed
    assert "+fused" in rec.provenance
    key = s.template_key(SQL)
    series = s.timeseries.series(key)
    assert series.deliveries == 1 and series.fused == 1
    from repro.obs.events import replay
    events = list(replay(str(tmp_path / "events.jsonl")))
    kinds = [e["ev"] for e in events]
    for k in ("submit", "pilot", "rate_solve", "final", "deliver", "audit"):
        assert k in kinds, f"missing {k} event"
    pilot = next(e for e in events if e["ev"] == "pilot")
    assert pilot["fused"] is True
    s.close()
