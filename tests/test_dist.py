"""Partitioned tables + shard-parallel execution (repro.dist).

The load-bearing property: for a fixed session seed, a table registered
with ANY shard count answers bit-identically — sampled finals, pilots,
shared-pilot herds, cached re-issues, and exact fallbacks included.  The
sampled block set is the one content-derived Bernoulli realization
restricted per shard, and all cross-shard state moves at per-block
granularity (blocks never straddle shards), so the merged statistics are
the same arrays a monolithic dispatch produces.
"""

import numpy as np
import pytest

from repro.api import Session, SessionConfig
from repro.core import CompositeAgg, ErrorSpec
from repro.core.taqa import Query
from repro.dist import (DistExecutor, ShardedTable, merge_block_stats,
                        reduce_group_totals, shard_block_ids)
from repro.dist.merge import ShardPart
from repro.engine import logical as L
from repro.engine.datagen import tpch_catalog
from repro.engine.executor import EmptySampleError, Executor
from repro.engine.expr import And, Col
from repro.engine.sampling import draw_block_ids

ROWS, BLOCK_ROWS = 24_000, 64
SEED = 11


@pytest.fixture(scope="module")
def catalog():
    return tpch_catalog(ROWS, BLOCK_ROWS, seed=3)


def q6_plan(seed, rate=0.12):
    pred = And(Col("l_shipdate").between(100, 1500), Col("l_quantity") < 24)
    plan = L.Aggregate(
        child=L.Filter(L.Scan("lineitem"), pred),
        aggs=(L.AggSpec("sum", Col("l_extendedprice") * Col("l_discount"),
                        "rev"),
              L.AggSpec("count", None, "cnt"),
              L.AggSpec("avg", Col("l_quantity"), "aq")),
        group_by="l_returnflag", max_groups=3)
    return L.rewrite_scans(
        plan, {"lineitem": L.SampleClause("block", rate, seed)})


def dist_executor(catalog, shards):
    ex = DistExecutor(dict(catalog))
    ex.register_sharded("lineitem", catalog["lineitem"], shards)
    return ex


# ---------------------------------------------------------------------------
# Shard geometry + restriction-based sub-draws
# ---------------------------------------------------------------------------

def test_shards_partition_blocks_disjoint_and_complete(catalog):
    table = catalog["lineitem"]
    st = ShardedTable.from_table(table, 3)
    assert st.num_blocks == table.num_blocks
    covered = []
    for s in st.shards:
        assert s.end_block > s.start_block
        assert s.table.num_blocks == s.num_blocks
        # global origin labels survive the slice
        assert int(np.asarray(s.table.block_id)[0]) == s.start_block
        covered.extend(range(s.start_block, s.end_block))
    assert covered == list(range(table.num_blocks))
    # shard data is the base table's slice, bit for bit
    s1 = st.shards[1]
    lo = s1.start_block * BLOCK_ROWS
    hi = s1.end_block * BLOCK_ROWS
    np.testing.assert_array_equal(
        np.asarray(s1.table.columns["l_quantity"]),
        np.asarray(table.columns["l_quantity"])[lo:hi])


def test_shard_counts_validated(catalog):
    table = catalog["lineitem"]
    with pytest.raises(ValueError):
        ShardedTable.from_table(table, 0)
    with pytest.raises(ValueError):
        ShardedTable.from_table(table, table.num_blocks + 1)


@pytest.mark.parametrize("shards", [1, 2, 3, 4, 7])
def test_sub_draws_union_to_the_monolithic_draw(catalog, shards):
    """Per-shard restriction of the one content-derived realization: the
    union equals the monolithic Bernoulli draw exactly, for any N."""
    table = catalog["lineitem"]
    st = ShardedTable.from_table(table, shards)
    global_ids, parts = shard_block_ids(table.num_blocks, 0.1, SEED, st)
    np.testing.assert_array_equal(global_ids,
                                  draw_block_ids(table.num_blocks, 0.1, SEED))
    rejoined = np.concatenate(
        [local + s.start_block for s, local in parts]) if parts else []
    np.testing.assert_array_equal(rejoined, global_ids)
    for s, local in parts:
        assert len(local) and local.min() >= 0
        assert local.max() < s.num_blocks


def test_merge_rejects_out_of_order_parts():
    a = ShardPart(0, np.array([4, 5]), np.zeros((2, 1, 2)))
    b = ShardPart(1, np.array([0, 1]), np.ones((2, 1, 2)))
    with pytest.raises(ValueError):
        merge_block_stats([a, b])
    ids, bs = merge_block_stats([b, a])
    np.testing.assert_array_equal(ids, [0, 1, 4, 5])
    sums, counts = reduce_group_totals(bs)
    assert sums.shape == (1, 1) and counts.shape == (1,)
    assert counts[0] == 2.0  # last channel is the row count


# ---------------------------------------------------------------------------
# Executor-level bit-identity
# ---------------------------------------------------------------------------

def test_final_bit_identity_across_shard_counts(catalog):
    results = {n: dist_executor(catalog, n).execute(q6_plan(7))
               for n in (1, 2, 4)}
    for n in (2, 4):
        np.testing.assert_array_equal(results[n].values, results[1].values)
        np.testing.assert_array_equal(results[n].group_counts,
                                      results[1].group_counts)
        np.testing.assert_array_equal(results[n].group_present,
                                      results[1].group_present)


def test_final_agrees_with_monolithic_route(catalog):
    """Cross-route agreement with the monolithic executor: counts and the
    group bitmap are bitwise equal (integer summands), values to f32
    rounding — the same standard the Pallas and XLA kernel routes meet."""
    ref = Executor(dict(catalog)).execute(q6_plan(7))
    res = dist_executor(catalog, 4).execute(q6_plan(7))
    np.testing.assert_array_equal(res.group_counts, ref.group_counts)
    np.testing.assert_array_equal(res.group_present, ref.group_present)
    np.testing.assert_allclose(res.values, ref.values, rtol=1e-6)
    assert res.scanned_bytes == ref.scanned_bytes
    infos = res.sample_infos["lineitem"]
    assert infos.n_sampled_blocks == ref.sample_infos["lineitem"].n_sampled_blocks


def test_pilot_statistics_bitwise_equal_to_monolithic(catalog):
    plan = L.strip_samples(q6_plan(0))
    ref = Executor(dict(catalog)).execute_pilot(plan, "lineitem", 0.08, SEED)
    for n in (1, 2, 4):
        ps = dist_executor(catalog, n).execute_pilot(
            plan, "lineitem", 0.08, SEED)
        assert ps.n_sampled_blocks == ref.n_sampled_blocks
        np.testing.assert_array_equal(ps.block_sums, ref.block_sums)
        np.testing.assert_array_equal(ps.group_present, ref.group_present)
        assert ps.scanned_bytes == ref.scanned_bytes


def test_join_pilot_pair_sums_merge_bitwise(catalog):
    """Lemma-4.8 block-pair statistics (join pilots) concatenate exactly."""
    plan = L.Aggregate(
        child=L.Join(L.Scan("lineitem"), L.Scan("orders"),
                     "l_orderkey", "o_orderkey"),
        aggs=(L.AggSpec("sum", Col("l_extendedprice"), "rev"),))
    ref = Executor(dict(catalog)).execute_pilot(
        plan, "lineitem", 0.08, SEED, pair_tables=("orders",))
    for n in (1, 3):
        ps = dist_executor(catalog, n).execute_pilot(
            plan, "lineitem", 0.08, SEED, pair_tables=("orders",))
        np.testing.assert_array_equal(ps.block_sums, ref.block_sums)
        np.testing.assert_array_equal(ps.pair_sums["orders"],
                                      ref.pair_sums["orders"])
        assert ps.right_total_blocks == ref.right_total_blocks


def test_empty_global_draw_raises_empty_sample_error(catalog):
    """The engine-wide empty-sample semantics survive sharding: a GLOBAL
    draw of zero blocks raises (TAQA's explicit exact fallback); a single
    empty shard merely contributes nothing (covered implicitly by the small
    rates elsewhere)."""
    ex = dist_executor(catalog, 4)
    n_blocks = catalog["lineitem"].num_blocks
    empty_seed = next(
        s for s in range(10_000)
        if len(draw_block_ids(n_blocks, 0.001, s)) == 0)
    with pytest.raises(EmptySampleError):
        ex.execute(q6_plan(empty_seed, rate=0.001))


def test_compile_cache_info_aggregates_shard_compilers(catalog):
    """Dist dispatches compile in per-shard executors; the top-level
    counters must include them (gateway/drain stats read those)."""
    ex = dist_executor(catalog, 2)
    assert ex.compile_cache_info().misses == 0
    ex.execute(q6_plan(7))
    first = ex.compile_cache_info()
    assert first.misses >= 2 and first.size >= 2  # one compile per shard
    ex.execute(q6_plan(8))  # same shapes: warm
    second = ex.compile_cache_info()
    assert second.misses == first.misses
    assert second.hits > first.hits


def test_per_shard_scanned_bytes_sum_to_monolithic_total(catalog):
    totals = {}
    for n in (1, 2, 4):
        ex = dist_executor(catalog, n)
        res = ex.execute(q6_plan(7))
        info = ex.shard_scan_info()["lineitem"]
        assert len(info) == n and all(b > 0 for b in info)
        totals[n] = sum(info)
        assert totals[n] == res.sample_infos["lineitem"].scanned_bytes
    assert totals[2] == totals[1] and totals[4] == totals[1]


def test_execute_batch_routes_dist_members_bit_identically(catalog):
    ex = dist_executor(catalog, 2)
    plans = [q6_plan(s) for s in (3, 4, 5, 6)]
    solo = [dist_executor(catalog, 2).execute(p) for p in plans]
    outs = ex.execute_batch(plans)
    for out, ref in zip(outs, solo):
        np.testing.assert_array_equal(out.values, ref.values)


def test_multi_table_sampling_falls_back_monolithically(catalog):
    """Plans sampling more than the sharded table run on the monolithic
    arrays — shard-count-independent by definition."""
    plan = L.Aggregate(
        child=L.Join(L.Scan("lineitem"), L.Scan("orders"),
                     "l_orderkey", "o_orderkey"),
        aggs=(L.AggSpec("sum", Col("l_extendedprice"), "rev"),))
    sampled = L.rewrite_scans(plan, {
        "lineitem": L.SampleClause("block", 0.2, 5),
        "orders": L.SampleClause("block", 0.5, 6)})
    ref = Executor(dict(catalog)).execute(sampled)
    for n in (2, 4):
        res = dist_executor(catalog, n).execute(sampled)
        np.testing.assert_array_equal(res.values, ref.values)


def test_plain_reregistration_drops_sharding(catalog):
    ex = dist_executor(catalog, 4)
    assert ex.sharded_tables() == {"lineitem": 4}
    ex.register_table("lineitem", catalog["lineitem"])
    assert ex.sharded_tables() == {}
    ref = Executor(dict(catalog)).execute(q6_plan(7))
    np.testing.assert_array_equal(ex.execute(q6_plan(7)).values, ref.values)


# ---------------------------------------------------------------------------
# Session-level acceptance: the TPC-H-style suite across shard counts
# ---------------------------------------------------------------------------

SUITE = [
    # q6-family filtered SUM (constant-varied herd below slides the cap)
    "SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
    "WHERE l_shipdate BETWEEN 100 AND 1500 AND l_quantity < 24 "
    "ERROR 5% CONFIDENCE 95%",
    # q1-family grouped multi-aggregate
    "SELECT COUNT(*) AS n, AVG(l_quantity) AS aq FROM lineitem "
    "GROUP BY l_returnflag ERROR 8% CONFIDENCE 90%",
    # ratio composite
    "SELECT SUM(l_extendedprice * l_discount) / SUM(l_extendedprice) AS r "
    "FROM lineitem ERROR 8% CONFIDENCE 90%",
    # PK-FK join
    "SELECT SUM(l_extendedprice) AS rev FROM lineitem "
    "JOIN orders ON l_orderkey = o_orderkey WHERE o_orderdate < 1200 "
    "ERROR 8% CONFIDENCE 90%",
    # exact (no ERROR clause)
    "SELECT SUM(l_quantity) AS q FROM lineitem WHERE l_quantity < 10",
]

HERD = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
        "WHERE l_quantity < {cap} ERROR 6% CONFIDENCE 90%")


def _run_suite(catalog, shards, pilot_workers=None):
    cfg = SessionConfig(large_table_rows=10_000, pilot_workers=pilot_workers) \
        if pilot_workers is not None else SessionConfig(large_table_rows=10_000)
    session = Session(seed=SEED, config=cfg)
    session.register_table("orders", catalog["orders"])
    session.register_table("lineitem", catalog["lineitem"], shards=shards)

    # one drain: the suite + a shared-pilot herd (verbatim re-issues share
    # ONE pilot, constant-varied members each pilot their own constant)
    sqls = list(SUITE)
    sqls += [HERD.format(cap=24)] * 3                   # herd: verbatim x3
    sqls += [HERD.format(cap=18 + 2 * i) for i in range(3)]  # constant-slid
    handles = [session.submit(q) for q in sqls]
    drain_stats_handles = session.drain()
    assert len(drain_stats_handles) == len(handles)
    drain1 = session.scheduler.last_drain

    # result-cache re-issue: identical resubmission answers from the cache
    reissue = session.submit(SUITE[0])
    session.drain()
    assert reissue.cached

    out = {
        "values": [np.asarray(h.result().values) for h in handles],
        "present": [np.asarray(h.result().group_present) for h in handles],
        "fallbacks": [h.fallback for h in handles],
        "reissue": np.asarray(reissue.result().values),
        "pilots_run": session.executor.pilots_run,
        "drain1": drain1,
        "shard_bytes": session.executor.shard_scan_info(),
    }
    session.close()
    return out


@pytest.fixture(scope="module")
def suite_runs(catalog):
    return {n: _run_suite(catalog, n) for n in (1, 2, 4)}


@pytest.mark.parametrize("shards", [2, 4])
def test_suite_bit_identical_to_single_shard(suite_runs, shards):
    base, run = suite_runs[1], suite_runs[shards]
    for vb, vr in zip(base["values"], run["values"]):
        np.testing.assert_array_equal(vb, vr)
    for pb, pr in zip(base["present"], run["present"]):
        np.testing.assert_array_equal(pb, pr)
    assert base["fallbacks"] == run["fallbacks"]
    np.testing.assert_array_equal(base["reissue"], run["reissue"])


def test_suite_shares_pilots_identically(suite_runs):
    """The shared-pilot herd runs the same number of pilot stages at every
    shard count (sharing keys are content-derived, not placement-derived)."""
    counts = {n: r["pilots_run"] for n, r in suite_runs.items()}
    assert counts[2] == counts[1] and counts[4] == counts[1]
    # 3 verbatim herd members shared ONE pilot: stages < approximate queries
    approx = sum(1 for s in SUITE if "ERROR" in s) + 6
    assert counts[1] < approx


def test_suite_shard_bytes_attribution(suite_runs):
    for n in (1, 2, 4):
        per_shard = suite_runs[n]["shard_bytes"]["lineitem"]
        assert len(per_shard) == n
    assert (sum(suite_runs[2]["shard_bytes"]["lineitem"])
            == sum(suite_runs[1]["shard_bytes"]["lineitem"]))
    assert (sum(suite_runs[4]["shard_bytes"]["lineitem"])
            == sum(suite_runs[1]["shard_bytes"]["lineitem"]))


def test_drain_records_pilot_fanout(suite_runs):
    """The constant-varied herd's pilot subgroups fanned out (>= 2 pilot
    subgroups in one drain group) and the drain surfaced the wall/serial
    accounting."""
    drain = suite_runs[1]["drain1"]
    assert drain.pilot_fanouts >= 1
    assert drain.pilot_fanout_serial_s > 0.0
    assert drain.pilot_fanout_wall_s > 0.0


def test_pilot_fanout_serial_and_concurrent_bit_identical(catalog):
    serial = _run_suite(catalog, 2, pilot_workers=0)
    conc = _run_suite(catalog, 2, pilot_workers=2)
    for vs, vc in zip(serial["values"], conc["values"]):
        np.testing.assert_array_equal(vs, vc)
    assert serial["pilots_run"] == conc["pilots_run"]


def test_session_rejects_shards_on_custom_executor(catalog):
    session = Session(executor=Executor(dict(catalog)))
    with pytest.raises(ValueError):
        session.register_table("lineitem", catalog["lineitem"], shards=2)
    session.close()


def test_rejected_shard_count_leaves_session_state_untouched(catalog):
    """An invalid shards= value is rejected BEFORE the table-generation
    bump: cached answers survive and nothing is invalidated over data that
    never changed."""
    session = Session(seed=SEED,
                      config=SessionConfig(large_table_rows=10_000))
    session.register_table("lineitem", catalog["lineitem"], shards=2)
    session.sql(SUITE[0])
    for bad in (0, -1, catalog["lineitem"].num_blocks + 1):
        with pytest.raises(ValueError, match="shards"):
            session.register_table("lineitem", catalog["lineitem"],
                                   shards=bad)
    again = session.sql(SUITE[0])
    assert again.cached  # the failed registrations evicted nothing
    session.close()


def test_register_table_replacement_invalidates_sharded_cache(catalog):
    session = Session(seed=SEED,
                      config=SessionConfig(large_table_rows=10_000))
    session.register_table("lineitem", catalog["lineitem"], shards=2)
    h1 = session.sql(SUITE[0])
    h2 = session.sql(SUITE[0])
    assert h2.cached
    session.register_table("lineitem", catalog["lineitem"], shards=4)
    h3 = session.sql(SUITE[0])
    assert not h3.cached  # replacement evicted the entry
    np.testing.assert_array_equal(h3.result().values, h1.result().values)
    session.close()


def test_hand_built_query_dist_matches_plain_session(catalog):
    """Builder/hand-built paths route through the same dist executor."""
    q = Query(child=L.Filter(L.Scan("lineitem"), Col("l_quantity") < 30),
              aggs=(CompositeAgg("q", "sum", Col("l_quantity")),))
    spec = ErrorSpec(error=0.06, confidence=0.9)
    vals = {}
    for shards in (1, 2, 4):
        s = Session(seed=SEED, config=SessionConfig(large_table_rows=10_000))
        s.register_table("lineitem", catalog["lineitem"], shards=shards)
        vals[shards] = np.asarray(s.execute(q, spec).result().values)
        s.close()
    np.testing.assert_array_equal(vals[2], vals[1])
    np.testing.assert_array_equal(vals[4], vals[1])
