"""End-to-end TAQA/PilotDB behaviour (Theorem 3.1 guarantee + fallbacks)."""

import numpy as np
import pytest

from repro.core import CompositeAgg, ErrorSpec, PilotDB, Query, RowSamplingAQP
from repro.engine import logical as L
from repro.engine.datagen import make_skewed, tpch_catalog
from repro.engine.executor import Executor
from repro.engine.expr import And, Col


@pytest.fixture(scope="module")
def db():
    cat = tpch_catalog(scale_rows=600_000, block_rows=32, seed=0)
    cat["skewed"] = make_skewed(400_000, 32, num_groups=4, seed=2)
    return PilotDB(Executor(cat), large_table_rows=50_000)


Q6_PRED = And(Col("l_shipdate").between(100, 1500),
              And(Col("l_discount").between(0.02, 0.08), Col("l_quantity") < 24))


def q6():
    return Query(child=L.Filter(L.Scan("lineitem"), Q6_PRED),
                 aggs=(CompositeAgg("revenue", "sum",
                                    Col("l_extendedprice") * Col("l_discount")),))


def rel_err(ans, exact, name, g=0):
    t = exact.values[exact.names.index(name), g]
    a = ans.values[ans.names.index(name), g]
    return abs(a - t) / abs(t)


def test_guarantee_simple_sum(db):
    spec = ErrorSpec(error=0.08, confidence=0.95)
    exact = db.exact(q6())
    errs = []
    for seed in range(8):
        ans = db.query(q6(), spec, seed=seed)
        assert ans.report.fallback is None, ans.report.fallback
        errs.append(rel_err(ans, exact, "revenue"))
    assert max(errs) <= spec.error  # all 8 runs within target


def test_sampled_plan_scans_less(db):
    spec = ErrorSpec(error=0.08, confidence=0.95)
    ans = db.query(q6(), spec, seed=1)
    total = ans.report.pilot_scanned_bytes + ans.report.final_scanned_bytes
    assert total < 0.5 * ans.report.exact_scanned_bytes


def test_guarantee_grouped_multi_agg(db):
    spec = ErrorSpec(error=0.10, confidence=0.9)
    q = Query(child=L.Scan("lineitem"),
              aggs=(CompositeAgg("qty", "sum", Col("l_quantity")),
                    CompositeAgg("cnt", "count"),
                    CompositeAgg("avgp", "avg", Col("l_extendedprice"))),
              group_by="l_returnflag", max_groups=3)
    exact = db.exact(q)
    for seed in (0, 1):
        ans = db.query(q, spec, seed=seed)
        assert ans.report.fallback is None
        for g in range(3):
            for name in ans.names:
                assert rel_err(ans, exact, name, g) <= spec.error


def test_guarantee_join_query(db):
    spec = ErrorSpec(error=0.10, confidence=0.9)
    q = Query(child=L.Filter(
        L.Join(L.Scan("lineitem"), L.Scan("orders"), "l_orderkey", "o_orderkey"),
        Col("o_orderdate") < 1200),
        aggs=(CompositeAgg("rev", "sum", Col("l_extendedprice")),))
    exact = db.exact(q)
    ans = db.query(q, spec, seed=3)
    assert ans.report.fallback is None
    assert rel_err(ans, exact, "rev") <= spec.error


def test_guarantee_skewed_data(db):
    spec = ErrorSpec(error=0.10, confidence=0.9)
    q = Query(child=L.Filter(L.Scan("skewed"), Col("s_filter") < 0.6),
              aggs=(CompositeAgg("m", "sum", Col("s_measure")),),
              group_by="s_group", max_groups=4)
    exact = db.exact(q)
    ans = db.query(q, spec, seed=5)
    assert ans.report.fallback is None
    for g in range(4):
        assert rel_err(ans, exact, "m", g) <= spec.error


def test_ratio_composite_aggregate(db):
    spec = ErrorSpec(error=0.10, confidence=0.9)
    q = Query(child=L.Filter(L.Scan("lineitem"), Col("l_shipdate") < 2000),
              aggs=(CompositeAgg("promo", "ratio",
                                 Col("l_extendedprice") * Col("l_discount"),
                                 expr2=Col("l_extendedprice")),))
    exact = db.exact(q)
    ans = db.query(q, spec, seed=4)
    assert ans.report.fallback is None
    assert rel_err(ans, exact, "promo") <= spec.error


def test_fallback_small_table():
    cat = tpch_catalog(scale_rows=5_000, block_rows=32, seed=3)
    db = PilotDB(Executor(cat), large_table_rows=50_000)
    ans = db.query(q6(), ErrorSpec(error=0.05, confidence=0.95))
    assert ans.report.fallback == "no large table to sample"
    # exact answer still returned
    exact = db.exact(q6())
    assert rel_err(ans, exact, "revenue") == 0.0


def test_fallback_infeasible_tight_error(db):
    """A 0.1% error target cannot be met at <=10% sampling here -> exact."""
    ans = db.query(q6(), ErrorSpec(error=0.001, confidence=0.99), seed=0)
    assert ans.report.fallback is not None
    exact = db.exact(q6())
    assert rel_err(ans, exact, "revenue") == 0.0


def test_fallback_empty_selection(db):
    q = Query(child=L.Filter(L.Scan("lineitem"), Col("l_shipdate") > 99_999),
              aggs=(CompositeAgg("s", "sum", Col("l_quantity")),))
    ans = db.query(q, ErrorSpec(error=0.05, confidence=0.95), seed=0)
    assert ans.report.fallback is not None  # L_mu <= 0 or no groups


def test_strict_group_coverage_falls_back(db):
    spec = ErrorSpec(error=0.10, confidence=0.9, group_min_size=10,
                     strict_group_coverage=True)
    q = Query(child=L.Scan("lineitem"),
              aggs=(CompositeAgg("qty", "sum", Col("l_quantity")),),
              group_by="l_returnflag", max_groups=3)
    ans = db.query(q, spec, seed=0)
    # covering 10-row groups needs theta_p > cap -> strict mode goes exact
    assert ans.report.fallback is not None
    assert "coverage" in ans.report.fallback


def test_report_latency_decomposition(db):
    ans = db.query(q6(), ErrorSpec(error=0.08, confidence=0.95), seed=2)
    r = ans.report
    assert r.pilot_time_s > 0 and r.final_time_s > 0 and r.plan_time_s >= 0
    assert r.plan is not None and 0 < min(r.plan.rates.values()) <= 0.10


def test_row_baseline_guarantee_and_cost(db):
    spec = ErrorSpec(error=0.10, confidence=0.9)
    rdb = RowSamplingAQP(db.ex, large_table_rows=50_000)
    exact = db.exact(q6())
    ans = rdb.query(q6(), spec, seed=11)
    assert ans.report.fallback is None
    assert rel_err(ans, exact, "revenue") <= spec.error
    # row sampling cannot skip blocks: final scan pays the full table
    li_bytes = db.ex.table_bytes("lineitem")
    assert ans.report.final_scanned_bytes >= li_bytes


def test_block_beats_row_scan_bytes(db):
    spec = ErrorSpec(error=0.10, confidence=0.9)
    rdb = RowSamplingAQP(db.ex, large_table_rows=50_000)
    a_blk = db.query(q6(), spec, seed=7)
    a_row = rdb.query(q6(), spec, seed=7)
    assert a_blk.report.final_scanned_bytes < a_row.report.final_scanned_bytes


def test_unsupported_aggregate_rejected():
    with pytest.raises(ValueError):
        CompositeAgg("bad", "max", Col("x"))
    with pytest.raises(ValueError):
        L.AggSpec("count_distinct", None, "cd")
