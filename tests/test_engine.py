"""Engine substrate: BlockTable, relational ops, samplers, cost model."""

import numpy as np
import pytest

from repro.engine import logical as L
from repro.engine import ops
from repro.engine.cost import exact_cost, plan_cost
from repro.engine.datagen import make_lineitem, make_orders, make_skewed, tpch_catalog
from repro.engine.executor import Executor
from repro.engine.expr import And, Col, Const, Not, Or, eval_expr
from repro.engine.sampling import block_sample, row_sample
from repro.engine.table import BlockTable


def small_table(n=100, br=8, seed=0, name="t"):
    rng = np.random.default_rng(seed)
    return BlockTable.from_numpy(
        name,
        {"k": np.arange(n, dtype=np.int32),
         "x": rng.normal(10.0, 2.0, n).astype(np.float32),
         "g": rng.integers(0, 3, n).astype(np.int32)},
        br,
    )


# -- BlockTable ---------------------------------------------------------------

def test_blocktable_padding_and_validity():
    t = small_table(n=13, br=8)
    assert t.padded_rows == 16
    assert t.num_blocks == 2
    assert int(np.asarray(t.valid).sum()) == 13
    assert t.num_origin_blocks == 2


def test_blocktable_gather_blocks_keeps_lineage():
    t = small_table(n=64, br=8)
    s = t.gather_blocks(np.array([3, 5]))
    assert s.padded_rows == 16
    bid = np.asarray(s.block_id)
    assert set(bid.tolist()) == {3, 5}
    np.testing.assert_array_equal(
        np.asarray(s.columns["k"])[:8], np.arange(24, 32))


def test_blocktable_rejects_bad_shapes():
    with pytest.raises(ValueError):
        BlockTable(name="bad", columns={"a": np.zeros(8), "b": np.zeros(9)},
                   block_rows=4, num_rows=8)


# -- expressions --------------------------------------------------------------

def test_expr_arithmetic_and_comparisons():
    cols = {"a": np.array([1.0, 2.0, 3.0]), "b": np.array([3.0, 2.0, 1.0])}
    e = (Col("a") * 2 + Col("b")) / 2
    np.testing.assert_allclose(np.asarray(eval_expr(e, cols)), [2.5, 3.0, 3.5])
    m = And(Col("a") >= 2, Or(Col("b") < 2, Not(Col("a").eq(2))))
    np.testing.assert_array_equal(np.asarray(eval_expr(m, cols)), [False, False, True])
    assert set(e.columns()) == {"a", "b"}
    assert Const(3.0).columns() == ()


def test_expr_between():
    cols = {"a": np.array([0.0, 5.0, 10.0])}
    np.testing.assert_array_equal(
        np.asarray(eval_expr(Col("a").between(1, 9), cols)), [False, True, False])


# -- relational ops -----------------------------------------------------------

def test_filter_marks_invalid_not_compacts():
    t = small_table(n=32, br=8)
    f = ops.filter_table(t, Col("x") > 10.0)
    assert f.padded_rows == t.padded_rows
    ref = np.asarray(t.columns["x"])[: t.num_rows] > 10.0
    assert int(np.asarray(f.valid).sum()) == int(ref.sum())


def test_join_unique_matches_numpy():
    rng = np.random.default_rng(3)
    left = BlockTable.from_numpy(
        "l", {"fk": rng.integers(0, 20, 64).astype(np.int32),
              "v": rng.normal(size=64).astype(np.float32)}, 8)
    right = BlockTable.from_numpy(
        "r", {"pk": np.arange(20, dtype=np.int32),
              "w": rng.normal(size=20).astype(np.float32)}, 4)
    j = ops.join_unique(left, right, "fk", "pk")
    lv = np.asarray(left.columns["fk"])[:64]
    rw = np.asarray(right.columns["w"])[:20]
    expect = rw[lv]
    got = np.asarray(j.columns["w"])[:64]
    mask = np.asarray(j.valid)[:64]
    assert mask.all()  # every fk has a match
    np.testing.assert_allclose(got[mask], expect[mask], rtol=1e-6)


def test_join_respects_right_validity():
    left = BlockTable.from_numpy("l", {"fk": np.array([0, 1, 2, 3], np.int32)}, 2)
    right = BlockTable.from_numpy(
        "r", {"pk": np.array([0, 1, 2, 3], np.int32),
              "w": np.arange(4, dtype=np.float32)}, 2)
    # invalidate right block 1 (pk 2,3)
    import jax.numpy as jnp
    rv = np.asarray(right.valid).copy()
    rv[2:] = False
    right = right.with_valid(jnp.asarray(rv))
    j = ops.join_unique(left, right, "fk", "pk")
    np.testing.assert_array_equal(np.asarray(j.valid)[:4], [True, True, False, False])


def test_join_name_collision_raises():
    l = BlockTable.from_numpy("l", {"k": np.zeros(4, np.int32), "v": np.zeros(4, np.float32)}, 2)
    r = BlockTable.from_numpy("r", {"pk": np.zeros(4, np.int32), "v": np.zeros(4, np.float32)}, 2)
    with pytest.raises(ValueError):
        ops.join_unique(l, r, "k", "pk")


def test_union_all_offsets_block_ids():
    a = small_table(n=16, br=8, seed=0)
    b = small_table(n=16, br=8, seed=1)
    u = ops.union_all([a, b])
    assert u.num_origin_blocks == 4
    bid = np.asarray(u.block_id)
    assert bid.min() == 0 and bid.max() == 3
    assert int(np.asarray(u.valid).sum()) == 32


def test_grouped_sums_and_counts():
    t = small_table(n=64, br=8)
    sums = np.asarray(ops.grouped_sums(t, [Col("x")], "g", 3))[0]
    counts = np.asarray(ops.grouped_counts(t, "g", 3))
    x = np.asarray(t.columns["x"])[:64]
    g = np.asarray(t.columns["g"])[:64]
    for gid in range(3):
        assert sums[gid] == pytest.approx(float(x[g == gid].sum()), rel=1e-5)
        assert counts[gid] == (g == gid).sum()


def test_block_group_sums_lineage_after_filter():
    t = small_table(n=64, br=8)
    f = ops.filter_table(t, Col("x") > 10.0)
    ids = np.array([1, 3, 6])
    bs = ops.block_group_sums(f, [Col("x")], None, 1, ids)
    x = np.asarray(t.columns["x"])
    for j, b in enumerate(ids):
        seg = x[b * 8:(b + 1) * 8]
        expect = seg[seg > 10.0].sum()
        assert bs[j, 0, 0] == pytest.approx(float(expect), rel=1e-5)


# -- samplers -----------------------------------------------------------------

def test_block_sample_scans_only_sampled_bytes():
    t = make_lineitem(20_000, 64, seed=0)
    s, info = block_sample(t, 0.1, seed=1)
    assert info.n_sampled_blocks == len(info.sampled_block_ids)
    assert info.scanned_bytes == info.n_sampled_blocks * 64 * t.row_bytes()
    assert info.scanned_bytes < t.total_bytes() / 5


def test_row_sample_pays_full_scan():
    t = make_lineitem(20_000, 64, seed=0)
    s, info = row_sample(t, 0.01, seed=1)
    assert info.scanned_bytes == t.total_bytes()
    kept = info.n_sampled_rows
    assert 0 < kept < 20_000 * 0.05


def test_block_sample_empty_outcome():
    t = small_table(n=32, br=8)
    s, info = block_sample(t, 1e-9, seed=0)
    assert info.n_sampled_blocks == 0
    assert int(np.asarray(s.valid).sum()) == 0


def test_sample_clause_validation():
    with pytest.raises(ValueError):
        L.SampleClause("block", 0.0)
    with pytest.raises(ValueError):
        L.SampleClause("shard", 0.5)


# -- executor -----------------------------------------------------------------

def test_executor_exact_matches_numpy():
    cat = tpch_catalog(40_000, 64, seed=0)
    ex = Executor(cat)
    plan = L.Aggregate(
        child=L.Filter(L.Scan("lineitem"), Col("l_discount") > 0.05),
        aggs=(L.AggSpec("sum", Col("l_extendedprice"), "s"),
              L.AggSpec("count", None, "c"),
              L.AggSpec("avg", Col("l_quantity"), "a")),
    )
    res = ex.execute(plan)
    li = cat["lineitem"].to_numpy()
    m = li["l_discount"] > 0.05
    assert res.scalar("s") == pytest.approx(float(li["l_extendedprice"][m].sum()), rel=1e-4)
    assert res.scalar("c") == pytest.approx(float(m.sum()))
    assert res.scalar("a") == pytest.approx(float(li["l_quantity"][m].mean()), rel=1e-4)


def test_executor_hajek_unbiased_single_table():
    cat = tpch_catalog(60_000, 32, seed=1)
    ex = Executor(cat)
    plan = L.Aggregate(child=L.Scan("lineitem"),
                       aggs=(L.AggSpec("sum", Col("l_quantity"), "s"),))
    truth = ex.execute(plan).scalar("s")
    ests = []
    for seed in range(30):
        p = L.rewrite_scans(plan, {"lineitem": L.SampleClause("block", 0.05, seed)})
        ests.append(ex.execute(p).scalar("s"))
    assert np.mean(ests) == pytest.approx(truth, rel=0.01)


def test_executor_ht_two_table_unbiased():
    cat = tpch_catalog(60_000, 32, seed=2)
    ex = Executor(cat)
    plan = L.Aggregate(
        child=L.Join(L.Scan("lineitem"), L.Scan("orders"), "l_orderkey", "o_orderkey"),
        aggs=(L.AggSpec("sum", Col("l_extendedprice"), "s"),))
    truth = ex.execute(plan).scalar("s")
    ests = []
    for seed in range(40):
        p = L.rewrite_scans(plan, {
            "lineitem": L.SampleClause("block", 0.2, seed),
            "orders": L.SampleClause("block", 0.3, seed + 1000)})
        ests.append(ex.execute(p).scalar("s"))
    assert np.mean(ests) == pytest.approx(truth, rel=0.05)


def test_pilot_stats_shapes_and_presence():
    cat = tpch_catalog(40_000, 64, seed=3)
    ex = Executor(cat)
    plan = L.Aggregate(child=L.Scan("lineitem"),
                       aggs=(L.AggSpec("sum", Col("l_quantity"), "s"),),
                       group_by="l_returnflag", max_groups=3)
    st = ex.execute_pilot(plan, "lineitem", 0.1, seed=4)
    assert st.block_sums.shape == (st.n_sampled_blocks, 3, 2)  # +__rows channel
    assert st.group_present.all()
    assert st.agg_names[-1] == "__rows"


def test_pilot_pair_sums_match_join_truth():
    cat = tpch_catalog(30_000, 64, seed=4)
    ex = Executor(cat)
    plan = L.Aggregate(
        child=L.Join(L.Scan("lineitem"), L.Scan("orders"), "l_orderkey", "o_orderkey"),
        aggs=(L.AggSpec("sum", Col("l_extendedprice"), "s"),))
    st = ex.execute_pilot(plan, "lineitem", 0.2, seed=5, pair_tables=("orders",))
    ps = st.pair_sums["orders"]
    assert ps.shape[0] == st.n_sampled_blocks
    assert ps.shape[1] == cat["orders"].num_blocks
    # row sums across right blocks == per-left-block sums
    np.testing.assert_allclose(ps[:, :, 0].sum(axis=1), st.block_sums[:, 0, 0], rtol=1e-4)


# -- cost model ---------------------------------------------------------------

def test_cost_model_sampling_discount():
    cat = tpch_catalog(40_000, 64, seed=5)
    plan = L.Aggregate(child=L.Scan("lineitem"),
                       aggs=(L.AggSpec("sum", Col("l_quantity"), "s"),))
    full = exact_cost(plan, cat)
    tenth = plan_cost(plan, cat, {"lineitem": 0.1})
    assert tenth == pytest.approx(0.1 * full, rel=1e-6)


def test_cost_model_join_counts_both_tables():
    cat = tpch_catalog(40_000, 64, seed=6)
    plan = L.Aggregate(
        child=L.Join(L.Scan("lineitem"), L.Scan("orders"), "l_orderkey", "o_orderkey"),
        aggs=(L.AggSpec("sum", Col("l_extendedprice"), "s"),))
    c = plan_cost(plan, cat, {"lineitem": 0.01})
    li_only = plan_cost(plan, cat, {"lineitem": 0.01, "orders": 0.0})
    assert c > li_only  # orders' scan contributes


def test_datagen_skewed_properties():
    t = make_skewed(30_000, 64, num_groups=5, seed=1)
    d = t.to_numpy()
    sizes = np.bincount(d["s_group"], minlength=5)
    assert sizes[0] > sizes[-1]  # Zipf skew
    assert (d["s_measure"] >= 0).all()
