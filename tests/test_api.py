"""Session front door: SQL parser, fluent builder, scheduler, seed threading.

Round-trip tests assert the parser lowers to the *same frozen dataclasses*
tests elsewhere hand-build (`tests/test_taqa.py`), so the SQL dialect and the
internal representation can never drift apart silently.
"""

import numpy as np
import pytest

from repro.api import (QueryFailedError, Session, SessionConfig,
                       SqlSyntaxError, avg_, count_, parse_sql, render_sql,
                       sum_)
from repro.core import CompositeAgg, ErrorSpec, Query
from repro.engine import logical as L
from repro.engine.datagen import tpch_catalog
from repro.engine.executor import EmptySampleError, Executor
from repro.engine.expr import And, Col

# The exact hand-built plans from tests/test_taqa.py
Q6_PRED = And(Col("l_shipdate").between(100, 1500),
              And(Col("l_discount").between(0.02, 0.08), Col("l_quantity") < 24))
Q6_HAND = Query(child=L.Filter(L.Scan("lineitem"), Q6_PRED),
                aggs=(CompositeAgg("revenue", "sum",
                                   Col("l_extendedprice") * Col("l_discount")),))
Q6_SQL = ("SELECT SUM(l_extendedprice * l_discount) AS revenue FROM lineitem "
          "WHERE l_shipdate BETWEEN 100 AND 1500 "
          "AND l_discount BETWEEN 0.02 AND 0.08 AND l_quantity < 24")

GROUPED_HAND = Query(
    child=L.Scan("lineitem"),
    aggs=(CompositeAgg("qty", "sum", Col("l_quantity")),
          CompositeAgg("cnt", "count"),
          CompositeAgg("avgp", "avg", Col("l_extendedprice"))),
    group_by="l_returnflag", max_groups=3)
GROUPED_SQL = ("SELECT SUM(l_quantity) AS qty, COUNT(*) AS cnt, "
               "AVG(l_extendedprice) AS avgp FROM lineitem "
               "GROUP BY l_returnflag MAXGROUPS 3")

JOIN_HAND = Query(
    child=L.Filter(
        L.Join(L.Scan("lineitem"), L.Scan("orders"), "l_orderkey", "o_orderkey"),
        Col("o_orderdate") < 1200),
    aggs=(CompositeAgg("rev", "sum", Col("l_extendedprice")),))
JOIN_SQL = ("SELECT SUM(l_extendedprice) AS rev FROM lineitem "
            "JOIN orders ON l_orderkey = o_orderkey WHERE o_orderdate < 1200")

RATIO_HAND = Query(
    child=L.Filter(L.Scan("lineitem"), Col("l_shipdate") < 2000),
    aggs=(CompositeAgg("promo", "ratio",
                       Col("l_extendedprice") * Col("l_discount"),
                       expr2=Col("l_extendedprice")),))
RATIO_SQL = ("SELECT SUM(l_extendedprice * l_discount) / SUM(l_extendedprice) "
             "AS promo FROM lineitem WHERE l_shipdate < 2000")


@pytest.fixture(scope="module")
def catalog():
    return tpch_catalog(scale_rows=600_000, block_rows=32, seed=0)


@pytest.fixture()
def session(catalog):
    return Session(catalog, seed=0)


# ---------------------------------------------------------------------------
# Parser: lowering equals the hand-built dataclass plans
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sql,hand", [
    (Q6_SQL, Q6_HAND),
    (GROUPED_SQL, GROUPED_HAND),
    (JOIN_SQL, JOIN_HAND),
    (RATIO_SQL, RATIO_HAND),
])
def test_parse_lowers_to_handbuilt_plan(sql, hand):
    parsed = parse_sql(sql)
    assert parsed.query == hand
    assert parsed.spec is None


def test_parse_error_clause():
    parsed = parse_sql(Q6_SQL + " ERROR 5% CONFIDENCE 95%")
    assert parsed.query == Q6_HAND
    assert parsed.spec == ErrorSpec(error=0.05, confidence=0.95)
    assert parsed.is_approximate


@pytest.mark.parametrize("sql,hand", [
    (Q6_SQL, Q6_HAND),
    (GROUPED_SQL, GROUPED_HAND),
    (JOIN_SQL, JOIN_HAND),
    (RATIO_SQL, RATIO_HAND),
])
def test_render_round_trip_matches_handbuilt(sql, hand):
    """parse -> lower -> render -> parse again reproduces the plan exactly."""
    for spec in (None, ErrorSpec(error=0.025, confidence=0.9)):
        rendered = render_sql(hand, spec)
        reparsed = parse_sql(rendered)
        assert reparsed.query == hand, rendered
        assert reparsed.spec == spec


@pytest.mark.parametrize("sql", [
    "SELECT SUM(a) * SUM(b) AS prod FROM t",
    "SELECT 0.5 * SUM(a) + 2 * SUM(b) AS mix FROM t",
    "SELECT -2 * SUM(a) + SUM(b) AS diff FROM t",
    "SELECT SUM(a) + -0.5 * SUM(b) AS mix FROM t",
    "SELECT SUM(a) + SUM(b) AS both FROM t WHERE NOT (x < 3 OR y >= 4)",
    "SELECT COUNT(*) AS n FROM t JOIN u ON a = b JOIN v ON c = d",
    "SELECT AVG(a - b) AS d FROM t WHERE (a + b) * 2 < 10 AND c != 4",
    "SELECT SUM(a) AS s FROM t WHERE x BETWEEN -1.5 AND 1 AND y < -3",
    "SELECT SUM(a) AS s FROM t GROUP BY g MAXGROUPS 7 ERROR 2.5% CONFIDENCE 97.5%",
])
def test_render_round_trip_clause_combinations(sql):
    p1 = parse_sql(sql)
    p2 = parse_sql(render_sql(p1.query, p1.spec))
    assert p2.query == p1.query
    assert p2.spec == p1.spec


@pytest.mark.parametrize("bad", [
    "SELECT SUM(a) FROM",                       # missing table
    "SUM(a) FROM t",                            # missing SELECT
    "SELECT MAX(a) AS m FROM t",                # non-linear aggregate
    "SELECT SUM(a) / COUNT(*) AS r FROM t",     # ratio needs SUM parts
    "SELECT SUM(a) AS s FROM t WHERE x",        # predicate isn't boolean
    "SELECT SUM(a) AS s FROM t ERROR 5 CONFIDENCE 95%",  # missing %
    "SELECT SUM(a) AS s FROM t trailing",       # trailing input
    "SELECT SUM(a) AS s FROM t ERROR 150% CONFIDENCE 95%",  # out of range
    "SELECT SUM(a) AS s FROM t ERROR 5% CONFIDENCE 100%",   # out of range
    "SELECT SUM(a) AS s FROM t GROUP BY g MAXGROUPS 2.5",   # non-integral
    "SELECT SUM(a) AS s FROM t WHERE 'A' BETWEEN 1 AND 2",  # Str in BETWEEN
])
def test_parse_rejects_bad_sql(bad):
    with pytest.raises(SqlSyntaxError):
        parse_sql(bad)


def test_default_agg_names():
    parsed = parse_sql("SELECT SUM(a), COUNT(*) FROM t")
    assert [a.name for a in parsed.query.aggs] == ["agg0", "agg1"]


# ---------------------------------------------------------------------------
# Dialect: qualified columns, string literals, canonical WHERE
# ---------------------------------------------------------------------------

def test_qualified_column_names_strip_to_canonical():
    """t.col is presentation sugar everywhere a column can appear; the
    lowered plan is identical to the unqualified spelling and render_sql
    emits the canonical unqualified form."""
    qualified = ("SELECT SUM(lineitem.l_extendedprice * lineitem.l_discount) "
                 "AS revenue FROM lineitem "
                 "JOIN orders ON lineitem.l_orderkey = orders.o_orderkey "
                 "WHERE orders.o_orderdate < 1200 "
                 "GROUP BY orders.o_orderpriority MAXGROUPS 5")
    plain = ("SELECT SUM(l_extendedprice * l_discount) AS revenue "
             "FROM lineitem JOIN orders ON l_orderkey = o_orderkey "
             "WHERE o_orderdate < 1200 GROUP BY o_orderpriority MAXGROUPS 5")
    pq, pp = parse_sql(qualified), parse_sql(plain)
    assert pq.query == pp.query
    rendered = render_sql(pq.query, pq.spec)
    assert "." not in rendered
    assert parse_sql(rendered).query == pq.query


def test_string_literals_parse_and_round_trip():
    from repro.engine.expr import Cmp, Str
    parsed = parse_sql("SELECT COUNT(*) AS n FROM t "
                       "WHERE flag = 'A' AND note != 'it''s'")
    pred = parsed.query.child.pred
    assert pred.left == Cmp("==", Col("flag"), Str("A"))
    assert pred.right == Cmp("!=", Col("note"), Str("it's"))
    rendered = render_sql(parsed.query)
    assert "'A'" in rendered and "'it''s'" in rendered
    assert parse_sql(rendered).query == parsed.query


def test_string_literal_executes_via_dictionary(catalog):
    """col = 'A' lowers to the dictionary code and answers exactly like the
    integer-constant spelling (both front-door directions)."""
    session = Session(dict(catalog), seed=0)
    session.register_dictionary("l_returnflag", ("A", "N", "R"))
    by_string = session.sql("SELECT COUNT(*) AS n FROM lineitem "
                            "WHERE l_returnflag = 'N'")
    by_code = session.sql("SELECT COUNT(*) AS n FROM lineitem "
                          "WHERE l_returnflag = 1")
    assert by_string.status == "done"
    assert by_string.scalar("n") == by_code.scalar("n") > 0
    # literal on the left works too, and != is the other supported op
    flipped = session.sql("SELECT COUNT(*) AS n FROM lineitem "
                          "WHERE 'N' = l_returnflag")
    assert flipped.scalar("n") == by_string.scalar("n")


def test_string_literal_rejections(catalog):
    from repro.api import UnsupportedSqlError
    session = Session(dict(catalog), seed=0)
    with pytest.raises(UnsupportedSqlError, match="no registered dictionary"):
        session.sql("SELECT COUNT(*) AS n FROM lineitem "
                    "WHERE l_returnflag = 'A'")
    session.register_dictionary("l_returnflag", ("A", "N", "R"))
    with pytest.raises(UnsupportedSqlError, match="not in the dictionary"):
        session.sql("SELECT COUNT(*) AS n FROM lineitem "
                    "WHERE l_returnflag = 'Z'")
    # order comparisons need a SORTED dictionary (code order == lex order);
    # an unsorted registration keeps the historical rejection
    session.register_dictionary("l_linestatus", ("O", "F"))
    with pytest.raises(UnsupportedSqlError, match="not lexicographically"):
        session.sql("SELECT COUNT(*) AS n FROM lineitem "
                    "WHERE l_linestatus < 'O'")
    with pytest.raises(UnsupportedSqlError, match="column"):
        session.sql("SELECT COUNT(*) AS n FROM lineitem "
                    "WHERE l_returnflag + 1 = 'A'")


def test_sorted_dictionary_order_comparisons(catalog):
    """A sorted dictionary lowers string ORDER comparisons to code-boundary
    comparisons — including literals outside the dictionary — and matches
    the integer-coded spelling exactly."""
    session = Session(dict(catalog), seed=0)
    session.register_dictionary("l_returnflag", ("A", "N", "R"))  # sorted
    count = lambda pred: session.sql(
        f"SELECT COUNT(*) AS n FROM lineitem WHERE {pred}").scalar("n")
    # col < 'N'  <=>  code < 1;  col <= 'N'  <=>  code < 2
    assert count("l_returnflag < 'N'") == count("l_returnflag < 1") > 0
    assert count("l_returnflag <= 'N'") == count("l_returnflag < 2")
    assert count("l_returnflag > 'A'") == count("l_returnflag >= 1")
    assert count("l_returnflag >= 'R'") == count("l_returnflag >= 2")
    # literal on the left mirrors the comparison:  'N' > col  <=>  col < 'N'
    assert count("'N' > l_returnflag") == count("l_returnflag < 'N'")
    # literals OUTSIDE the dictionary still order correctly via bisection
    assert count("l_returnflag < 'B'") == count("l_returnflag < 1")  # only 'A'
    assert count("l_returnflag < 'Z'") == count("l_returnflag < 3")  # all
    assert count("l_returnflag > 'Z'") == 0
    session.close()


def test_sorted_dictionary_order_extremes_below_first_and_above_last(catalog):
    """Bisection boundaries at the dictionary's edges: literals ordering
    BELOW the first entry lower to the 0 boundary (nothing is smaller,
    everything is >=), literals ABOVE the last entry to the N boundary
    (everything is smaller, nothing is >) — for in- and out-of-dictionary
    spellings alike."""
    session = Session(dict(catalog), seed=0)
    session.register_dictionary("l_returnflag", ("A", "N", "R"))  # sorted
    count = lambda pred: session.sql(
        f"SELECT COUNT(*) AS n FROM lineitem WHERE {pred}").scalar("n")
    total = session.sql("SELECT COUNT(*) AS n FROM lineitem "
                        "WHERE l_returnflag >= 0").scalar("n")
    # below the first entry ('0' < 'A'): empty/full halves at boundary 0
    assert count("l_returnflag < '0'") == 0
    assert count("l_returnflag <= '0'") == 0
    assert count("l_returnflag > '0'") == total
    assert count("l_returnflag >= '0'") == total
    # at the first entry: strict below is empty, inclusive above is full
    assert count("l_returnflag < 'A'") == 0
    assert count("l_returnflag >= 'A'") == total
    # above the last entry ('Z' > 'R'): full/empty halves at boundary 3
    assert count("l_returnflag < 'Z'") == total
    assert count("l_returnflag <= 'Z'") == total
    assert count("l_returnflag > 'Z'") == 0
    assert count("l_returnflag >= 'Z'") == 0
    # at the last entry: inclusive below is full, strict above is empty
    assert count("l_returnflag <= 'R'") == total
    assert count("l_returnflag > 'R'") == 0
    session.close()


def test_dictionary_equality_against_absent_literal_rejected(catalog):
    """Equality against a literal OUTSIDE the dictionary is rejected for
    sorted and unsorted dictionaries alike — unlike order comparisons,
    equality has no bisection-boundary lowering (an absent value can match
    no code, and silently returning zero rows would mask typos)."""
    from repro.api import UnsupportedSqlError
    session = Session(dict(catalog), seed=0)
    session.register_dictionary("l_returnflag", ("A", "N", "R"))    # sorted
    session.register_dictionary("l_linestatus", ("O", "F"))         # unsorted
    for column in ("l_returnflag", "l_linestatus"):
        for op in ("=", "!="):
            with pytest.raises(UnsupportedSqlError,
                               match="not in the dictionary"):
                session.sql(f"SELECT COUNT(*) AS n FROM lineitem "
                            f"WHERE {column} {op} 'Q'")
    # sorted dictionaries still accept the same absent literal for ORDER
    # comparisons (the bisection boundary is well-defined either way)
    assert session.sql("SELECT COUNT(*) AS n FROM lineitem "
                       "WHERE l_returnflag < 'Q'").status == "done"
    # unsorted ones reject order comparisons even for present literals
    with pytest.raises(UnsupportedSqlError, match="not lexicographically"):
        session.sql("SELECT COUNT(*) AS n FROM lineitem "
                    "WHERE l_linestatus < 'O'")
    session.close()


# ---------------------------------------------------------------------------
# HAVING: post-aggregation group filter
# ---------------------------------------------------------------------------

def test_having_parses_and_round_trips():
    from repro.api import HavingClause
    sql = ("SELECT SUM(l_quantity) AS q FROM lineitem "
           "GROUP BY l_returnflag MAXGROUPS 3 HAVING q >= 100 "
           "ERROR 5% CONFIDENCE 95%")
    parsed = parse_sql(sql)
    assert parsed.having == HavingClause("q", ">=", 100.0)
    rendered = render_sql(parsed.query, parsed.spec, parsed.having)
    assert parse_sql(rendered) == parsed
    # negative literals and every comparison operator survive the trip
    for op in ("<", "<=", ">", ">=", "=", "!="):
        p = parse_sql(f"SELECT COUNT(*) AS n FROM t HAVING n {op} -3")
        assert parse_sql(render_sql(p.query, p.spec, p.having)) == p


def test_having_unknown_aggregate_rejected():
    with pytest.raises(SqlSyntaxError, match="not a SELECT output"):
        parse_sql("SELECT COUNT(*) AS n FROM t GROUP BY g HAVING m > 1")


def test_having_filters_groups_on_answer(catalog):
    """HAVING clears failing groups from group_present; estimates are
    untouched, and the unfiltered spelling still sees every group."""
    session = Session(dict(catalog), seed=0)
    base = session.sql("SELECT SUM(l_quantity) AS q FROM lineitem "
                       "GROUP BY l_returnflag ERROR 5% CONFIDENCE 95%")
    vals = np.asarray(base.result().values[0])
    present = np.asarray(base.result().group_present)
    assert present.all()
    cut = float(np.sort(vals)[-2])  # keep only groups >= 2nd largest
    h = session.sql("SELECT SUM(l_quantity) AS q FROM lineitem "
                    f"GROUP BY l_returnflag HAVING q >= {cut} "
                    "ERROR 5% CONFIDENCE 95%")
    np.testing.assert_array_equal(np.asarray(h.result().group_present),
                                  vals >= cut)
    # values are the same estimates — HAVING filters membership only
    np.testing.assert_array_equal(np.asarray(h.result().values), base.result().values)
    session.close()


def test_having_variants_share_one_cached_base_answer(catalog):
    """HAVING is not part of the plan/seed/cache key: HAVING-varied
    re-issues of one query hit ONE cached base answer and re-filter it."""
    session = Session(dict(catalog), seed=0)
    template = ("SELECT SUM(l_quantity) AS q FROM lineitem "
                "GROUP BY l_returnflag{having} ERROR 5% CONFIDENCE 95%")
    first = session.sql(template.format(having=" HAVING q > 0"))
    assert not first.cached and np.asarray(first.result().group_present).all()
    tight = session.sql(template.format(having=" HAVING q > 1e12"))
    assert tight.cached  # same (query, spec, seed) -> the cached base
    assert not np.asarray(tight.result().group_present).any()
    bare = session.sql(template.format(having=""))
    assert bare.cached
    assert np.asarray(bare.result().group_present).all()
    session.close()


# ---------------------------------------------------------------------------
# LIMIT / ORDER BY: post-aggregation top-n selection
# ---------------------------------------------------------------------------

def test_limit_parses_and_round_trips():
    from repro.api import LimitClause
    sql = ("SELECT SUM(l_quantity) AS q FROM lineitem "
           "GROUP BY l_returnflag MAXGROUPS 3 HAVING q >= 100 "
           "ORDER BY q DESC LIMIT 2 ERROR 5% CONFIDENCE 95%")
    parsed = parse_sql(sql)
    assert parsed.limit == LimitClause(2, order_by="q", desc=True)
    rendered = render_sql(parsed.query, parsed.spec, parsed.having,
                          parsed.limit)
    assert parse_sql(rendered) == parsed
    # bare LIMIT, explicit ASC (canonicalized away), and no-ERROR spellings
    for sql in ("SELECT COUNT(*) AS n FROM t LIMIT 5",
                "SELECT COUNT(*) AS n FROM t GROUP BY g ORDER BY n ASC "
                "LIMIT 1",
                "SELECT COUNT(*) AS n FROM t ORDER BY n LIMIT 3 "
                "ERROR 5% CONFIDENCE 95%"):
        p = parse_sql(sql)
        assert p.limit is not None and not p.limit.desc
        assert parse_sql(render_sql(p.query, p.spec, p.having, p.limit)) == p


def test_limit_rejections():
    with pytest.raises(SqlSyntaxError, match="ORDER BY requires LIMIT"):
        parse_sql("SELECT COUNT(*) AS n FROM t ORDER BY n DESC")
    with pytest.raises(SqlSyntaxError, match="not a SELECT output"):
        parse_sql("SELECT COUNT(*) AS n FROM t ORDER BY m LIMIT 2")
    with pytest.raises(SqlSyntaxError, match="positive integer"):
        parse_sql("SELECT COUNT(*) AS n FROM t LIMIT 0")
    with pytest.raises(SqlSyntaxError, match="positive integer"):
        parse_sql("SELECT COUNT(*) AS n FROM t LIMIT 2.5")


def test_limit_selects_top_groups_on_answer(catalog):
    """ORDER BY <agg> DESC LIMIT n keeps the n largest-estimate groups in
    group_present; estimates are untouched; bare LIMIT keeps the first n
    present groups in group-id order."""
    session = Session(dict(catalog), seed=0)
    base = session.sql("SELECT SUM(l_quantity) AS q FROM lineitem "
                       "GROUP BY l_returnflag ERROR 5% CONFIDENCE 95%")
    vals = np.asarray(base.result().values[0])
    assert np.asarray(base.result().group_present).all()
    top = session.sql("SELECT SUM(l_quantity) AS q FROM lineitem "
                      "GROUP BY l_returnflag ORDER BY q DESC LIMIT 1 "
                      "ERROR 5% CONFIDENCE 95%")
    expect = np.zeros(len(vals), bool)
    expect[int(np.argmax(vals))] = True
    np.testing.assert_array_equal(np.asarray(top.result().group_present),
                                  expect)
    np.testing.assert_array_equal(np.asarray(top.result().values),
                                  base.result().values)
    first2 = session.sql("SELECT SUM(l_quantity) AS q FROM lineitem "
                         "GROUP BY l_returnflag LIMIT 2 "
                         "ERROR 5% CONFIDENCE 95%")
    got = np.asarray(first2.result().group_present)
    assert got.sum() == 2 and got[:2].all()
    session.close()


def test_limit_applies_after_having(catalog):
    """HAVING filters first, then LIMIT ranks the survivors — a group
    cleared by HAVING can never be selected by LIMIT."""
    session = Session(dict(catalog), seed=0)
    base = session.sql("SELECT SUM(l_quantity) AS q FROM lineitem "
                       "GROUP BY l_returnflag ERROR 5% CONFIDENCE 95%")
    vals = np.asarray(base.result().values[0])
    cut = float(np.sort(vals)[-1])  # HAVING q < max clears the top group
    h = session.sql("SELECT SUM(l_quantity) AS q FROM lineitem "
                    f"GROUP BY l_returnflag HAVING q < {cut} "
                    "ORDER BY q DESC LIMIT 1 ERROR 5% CONFIDENCE 95%")
    got = np.asarray(h.result().group_present)
    runner_up = np.zeros(len(vals), bool)
    runner_up[int(np.argsort(vals)[-2])] = True
    np.testing.assert_array_equal(got, runner_up)
    session.close()


def test_limit_variants_share_one_cached_base_answer(catalog):
    """LIMIT (like HAVING) is not part of the plan/seed/cache key:
    LIMIT-varied re-issues hit ONE cached base answer and re-select it."""
    session = Session(dict(catalog), seed=0)
    template = ("SELECT SUM(l_quantity) AS q FROM lineitem "
                "GROUP BY l_returnflag{limit} ERROR 5% CONFIDENCE 95%")
    first = session.sql(template.format(limit=""))
    assert not first.cached
    n_present = int(np.asarray(first.result().group_present).sum())
    assert n_present > 1
    top1 = session.sql(template.format(limit=" ORDER BY q DESC LIMIT 1"))
    assert top1.cached  # same (query, spec, seed) -> the cached base
    assert int(np.asarray(top1.result().group_present).sum()) == 1
    bare = session.sql(template.format(limit=""))
    assert bare.cached
    assert int(np.asarray(bare.result().group_present).sum()) == n_present
    session.close()


def test_limit_order_by_unknown_aggregate_rejected_by_builder_path(catalog):
    from repro.api import LimitClause, UnsupportedSqlError
    session = Session(dict(catalog), seed=0)
    with pytest.raises(UnsupportedSqlError, match="unknown aggregate"):
        session.submit_query(
            Q6_HAND, ErrorSpec(error=0.05, confidence=0.95),
            limit=LimitClause(1, order_by="nope"))
    session.close()


def test_nested_filters_render_one_canonical_where():
    """Nested Filter nodes collapse into ONE WHERE conjunction with stable
    term order (application order: innermost filter first), right-folded
    exactly as the parser folds — render∘parse is a fixpoint."""
    nested = Query(
        child=L.Filter(
            L.Filter(L.Filter(L.Scan("t"),
                              And(Col("a") < 1, Col("b") < 2)),
                     Col("c") < 3),
            Col("d") < 4),
        aggs=(CompositeAgg("n", "count"),))
    rendered = render_sql(nested)
    assert rendered == ("SELECT COUNT(*) AS n FROM t "
                        "WHERE a < 1 AND b < 2 AND c < 3 AND d < 4")
    reparsed = parse_sql(rendered)
    # the canonical form is a single Filter with a right-folded AND chain
    assert isinstance(reparsed.query.child, L.Filter)
    assert not isinstance(reparsed.query.child.child, L.Filter)
    assert render_sql(reparsed.query) == rendered  # fixpoint
    # left-nested hand-built conjunctions canonicalize the same way
    left_nested = Query(
        child=L.Filter(L.Scan("t"),
                       And(And(Col("a") < 1, Col("b") < 2), Col("c") < 3)),
        aggs=(CompositeAgg("n", "count"),))
    assert render_sql(left_nested) == ("SELECT COUNT(*) AS n FROM t "
                                       "WHERE a < 1 AND b < 2 AND c < 3")
    assert render_sql(parse_sql(render_sql(left_nested)).query) == \
        render_sql(left_nested)


# ---------------------------------------------------------------------------
# Builder: the typed twin lowers identically
# ---------------------------------------------------------------------------

def test_builder_lowers_like_sql(session):
    q, spec = (session.table("lineitem")
               .where(Q6_PRED)
               .agg(sum_(Col("l_extendedprice") * Col("l_discount")).as_("revenue"))
               .error(0.05, 0.95)
               .build())
    assert q == Q6_HAND
    assert spec == ErrorSpec(error=0.05, confidence=0.95)


def test_builder_composites(session):
    b = session.table("lineitem").agg(
        (sum_(Col("l_extendedprice") * Col("l_discount"))
         / sum_(Col("l_extendedprice"))).as_("promo"),
        (sum_(Col("a")) * sum_(Col("b"))).as_("prod"),
        (0.5 * sum_(Col("a")) + 2 * sum_(Col("b"))).as_("mix"),
        count_().as_("n"),
        avg_(Col("l_quantity")).as_("avg_qty"))
    q, _ = b.build()
    kinds = [a.kind for a in q.aggs]
    assert kinds == ["ratio", "product", "add", "count", "avg"]
    assert q.aggs[2].weights == (0.5, 2.0)


def test_builder_composite_preserves_operand_name():
    """An .as_() name on an operand carries through /,*,+ composition."""
    ratio = sum_(Col("a")).as_("promo") / sum_(Col("b"))
    assert ratio.to_composite("agg0").name == "promo"
    mix = 0.5 * sum_(Col("a")) + sum_(Col("b")).as_("mix")
    assert mix.to_composite("agg0").name == "mix"
    # an explicit name on the composite still wins
    assert (ratio.as_("r2")).to_composite("agg0").name == "r2"


def test_builder_rejects_weighted_non_add_composites(session):
    """A scalar coefficient outside '+' must raise, never silently drop."""
    with pytest.raises(TypeError):
        (0.5 * sum_(Col("a"))) / sum_(Col("b"))
    with pytest.raises(TypeError):
        2 * sum_(Col("a")) * sum_(Col("b"))
    with pytest.raises(TypeError):
        sum_(Col("a")) / (2 * sum_(Col("b")))
    with pytest.raises(TypeError):
        session.table("lineitem").agg(2 * sum_(Col("l_quantity"))).build()
    # scalar operands of / and + get a descriptive TypeError, not an
    # AttributeError from inside the Agg internals
    with pytest.raises(TypeError, match="Table-2"):
        sum_(Col("a")) / 2
    with pytest.raises(TypeError, match="Table-2"):
        sum_(Col("a")) + 3


def test_bad_session_spec_kwargs_fail_at_construction(catalog):
    """A server-side tunable typo must fail loudly when the Session is
    built, not masquerade as every client's SQL syntax error."""
    with pytest.raises(TypeError):
        Session(catalog, config=SessionConfig(
            spec_kwargs={"min_pilot_block": 50}))  # typo: missing 's'


def test_builder_error_applies_session_spec_kwargs(catalog):
    """Both front doors must run identical TAQA tunables (interchangeable)."""
    session = Session(catalog, seed=0,
                      config=SessionConfig(spec_kwargs={"min_pilot_blocks": 50}))
    _, built_spec = (session.table("lineitem")
                     .agg(sum_(Col("l_quantity")).as_("q"))
                     .error(0.05, 0.95).build())
    parsed_spec = session.sql("SELECT SUM(l_quantity) AS q FROM lineitem "
                              "ERROR 5% CONFIDENCE 95%").spec
    assert built_spec == parsed_spec
    assert built_spec.min_pilot_blocks == 50
    # explicit kwargs on .error() still win over the session override
    _, spec2 = (session.table("lineitem")
                .agg(sum_(Col("l_quantity")).as_("q"))
                .error(0.05, 0.95, min_pilot_blocks=40).build())
    assert spec2.min_pilot_blocks == 40


def test_builder_join_and_group(session):
    q, _ = (session.table("lineitem")
            .join("orders", "l_orderkey", "o_orderkey")
            .where(Col("o_orderdate") < 1200)
            .agg(sum_(Col("l_extendedprice")).as_("rev"))
            .build())
    assert q == JOIN_HAND
    qg, _ = (session.table("lineitem")
             .group_by("l_returnflag")  # max_groups inferred from catalog
             .agg(sum_(Col("l_quantity")).as_("qty"))
             .build())
    assert qg.max_groups == 3


def test_max_groups_inference_from_catalog(session):
    parsed_sql = "SELECT SUM(l_quantity) AS qty FROM lineitem GROUP BY l_returnflag"
    handle = session.sql(parsed_sql)
    assert handle.query.max_groups == 3
    assert handle.status == "done"


# ---------------------------------------------------------------------------
# Session execution: the acceptance path
# ---------------------------------------------------------------------------

def test_session_sql_avg_guaranteed_answer(catalog):
    """Acceptance: AVG + WHERE + ERROR 5% CONFIDENCE 95% through session.sql
    returns a guaranteed (non-fallback) ApproxAnswer within the target."""
    session = Session(
        catalog, seed=0,
        config=SessionConfig(spec_kwargs={"max_final_rate": 0.25}))
    handle = session.sql("SELECT AVG(l_extendedprice) AS avgp FROM lineitem "
                         "WHERE l_quantity < 24 ERROR 5% CONFIDENCE 95%")
    assert handle.status == "done"
    assert handle.fallback is None
    exact = session.sql("SELECT AVG(l_extendedprice) AS avgp FROM lineitem "
                        "WHERE l_quantity < 24")
    rel = abs(handle.scalar("avgp") - exact.scalar("avgp")) / exact.scalar("avgp")
    assert rel <= 0.05
    # and it sampled, rather than scanning everything
    r = handle.report
    assert r.pilot_scanned_bytes + r.final_scanned_bytes < r.exact_scanned_bytes


def test_seed_threading_bit_identical_sessions(catalog):
    sql = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
           "WHERE l_quantity < 24 ERROR 8% CONFIDENCE 95%")
    a = Session(catalog, seed=11).sql(sql)
    b = Session(catalog, seed=11).sql(sql)
    assert a.seed == b.seed
    assert np.array_equal(a.result().values, b.result().values)
    assert a.report.plan.rates == b.report.plan.rates
    c = Session(catalog, seed=12).sql(sql)
    assert c.seed != a.seed


def test_seeds_assigned_at_submission_not_drain(catalog):
    """Scheduler batching must not change sampling: submit+drain replays the
    synchronous path bit-for-bit for the same session seed."""
    sql1 = ("SELECT SUM(l_quantity) AS qty FROM lineitem "
            "WHERE l_shipdate < 2000 ERROR 8% CONFIDENCE 95%")
    sql2 = ("SELECT COUNT(*) AS n FROM lineitem "
            "WHERE l_discount BETWEEN 0.02 AND 0.08 ERROR 8% CONFIDENCE 95%")
    sync = Session(catalog, seed=3)
    r1, r2 = sync.sql(sql1), sync.sql(sql2)
    queued = Session(catalog, seed=3)
    h1, h2 = queued.submit(sql1), queued.submit(sql2)
    queued.drain()
    assert np.array_equal(h1.result().values, r1.result().values)
    assert np.array_equal(h2.result().values, r2.result().values)


def test_exact_sql_without_error_clause(session):
    handle = session.sql("SELECT SUM(l_quantity) AS qty FROM lineitem")
    assert handle.status == "done"
    assert handle.spec is None
    assert handle.fallback == "requested exact"
    assert handle.scalar("qty") > 0


# ---------------------------------------------------------------------------
# Failure capture: nothing raises through the client
# ---------------------------------------------------------------------------

def test_empty_sample_error_exact_fallback_end_to_end(session, monkeypatch):
    """EmptySampleError from the final sampled scan surfaces as an explicit
    exact fallback on the handle — never as an exception to the client."""
    real_execute = Executor.execute

    def flaky_execute(self, plan):
        if any(s.sample is not None for s in plan.scans()):
            raise EmptySampleError("lineitem", "block", 0.01)
        return real_execute(self, plan)

    monkeypatch.setattr(Executor, "execute", flaky_execute)
    handle = session.sql(Q6_SQL + " ERROR 8% CONFIDENCE 95%")
    assert handle.status == "done"
    assert handle.report.fallback is not None
    assert "final sample empty" in handle.report.fallback
    # the fallback is the exact answer, not a fabricated estimate
    exact = session.sql(Q6_SQL)
    assert handle.scalar("revenue") == exact.scalar("revenue")


def test_zero_selectivity_predicate_falls_back(session):
    handle = session.sql("SELECT SUM(l_quantity) AS s FROM lineitem "
                         "WHERE l_shipdate > 99999 ERROR 5% CONFIDENCE 95%")
    assert handle.status == "done"
    assert handle.report.fallback is not None
    assert handle.scalar("s") == 0.0


def test_execution_failure_captured_on_handle(session):
    handle = session.sql("SELECT SUM(nope) AS s FROM lineitem "
                         "ERROR 5% CONFIDENCE 95%")
    assert handle.status == "failed"
    assert handle.error is not None
    with pytest.raises(QueryFailedError):
        handle.result()


def test_unknown_table_rejected(session):
    with pytest.raises(KeyError):
        session.table("nope")
    handle = session.sql("SELECT COUNT(*) AS n FROM nope")
    assert handle.status == "failed"


def test_register_table(catalog):
    session = Session({"lineitem": catalog["lineitem"]}, seed=0)
    assert session.tables() == ["lineitem"]
    session.register_table("orders", catalog["orders"])
    assert "orders" in session.tables()
    handle = session.sql("SELECT COUNT(*) AS n FROM orders")
    assert handle.status == "done" and handle.scalar("n") > 0


def test_register_table_invalidates_group_statistics(catalog):
    """Replacing a table must refresh cached MAXGROUPS inference."""
    import dataclasses as dc

    import jax.numpy as jnp

    session = Session(dict(catalog), seed=0)
    assert session.infer_max_groups("lineitem", "l_returnflag") == 3
    old = catalog["lineitem"]
    wider = dc.replace(
        old,
        columns={**old.columns,
                 "l_returnflag": jnp.asarray(
                     np.arange(old.padded_rows) % 6,
                     old.columns["l_returnflag"].dtype)},
        valid=old.valid, block_id=old.block_id,
        num_origin_blocks=old.num_origin_blocks)
    session.register_table("lineitem", wider)
    assert session.infer_max_groups("lineitem", "l_returnflag") == 6
    handle = session.sql("SELECT COUNT(*) AS n FROM lineitem "
                         "GROUP BY l_returnflag")
    assert handle.query.max_groups == 6


def test_group_by_joined_table_column(session):
    """GROUP BY may name a joined table's column; inference consults every
    table in the FROM/JOIN chain, not only the base."""
    handle = session.sql("SELECT SUM(l_quantity) AS qty FROM lineitem "
                         "JOIN orders ON l_orderkey = o_orderkey "
                         "GROUP BY o_orderpriority")
    assert handle.status == "done"
    assert handle.query.max_groups == \
        session.infer_max_groups("orders", "o_orderpriority")
    builder_q, _ = (session.table("lineitem")
                    .join("orders", "l_orderkey", "o_orderkey")
                    .group_by("o_orderpriority")
                    .agg(sum_(Col("l_quantity")).as_("qty"))
                    .build())
    assert builder_q.max_groups == handle.query.max_groups


def test_group_by_non_integer_column_rejected(session):
    """GROUP BY on a float-coded column must be refused, not silently
    collapsed into one group."""
    from repro.api import UnsupportedSqlError
    with pytest.raises(UnsupportedSqlError):
        session.sql("SELECT SUM(l_quantity) AS q FROM lineitem "
                    "GROUP BY l_discount")
    # an explicit MAXGROUPS matching the integer-coded domain still works
    handle = session.sql("SELECT SUM(l_quantity) AS q FROM lineitem "
                         "GROUP BY l_returnflag MAXGROUPS 3")
    assert handle.status == "done"


def test_group_by_id_cardinality_rejected_not_oom(session):
    """An id-column GROUP BY through the front door must be refused — the
    dense per-(block, group) buffers would otherwise OOM the server."""
    from repro.api import UnsupportedSqlError
    with pytest.raises(UnsupportedSqlError, match="limit"):
        session.sql("SELECT COUNT(*) AS n FROM lineitem "
                    "GROUP BY l_orderkey ERROR 5% CONFIDENCE 95%")
    with pytest.raises(UnsupportedSqlError, match="limit"):
        session.sql("SELECT COUNT(*) AS n FROM lineitem "
                    "GROUP BY l_returnflag MAXGROUPS 1000000")


def test_maxgroups_below_domain_rejected(catalog):
    """MAXGROUPS below the observed domain would silently merge overflow
    groups into the last group — refuse instead of returning wrong sums.
    Rejected queries consume no seed, so replay stays deterministic."""
    from repro.api import UnsupportedSqlError
    good = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
            "WHERE l_quantity < 24 ERROR 8% CONFIDENCE 95%")
    a = Session(catalog, seed=21)
    with pytest.raises(UnsupportedSqlError, match="domain"):
        a.sql("SELECT SUM(l_quantity) AS q FROM lineitem "
              "GROUP BY l_returnflag MAXGROUPS 2")
    ha = a.sql(good)
    b = Session(catalog, seed=21)
    hb = b.sql(good)  # no rejected query before it
    assert ha.seed == hb.seed
    assert np.array_equal(ha.result().values, hb.result().values)


def test_unknown_table_with_group_by_is_captured(session):
    # inference is advisory: the missing table fails at execution, on the
    # handle — never as a KeyError through sql()/submit()
    handle = session.sql("SELECT COUNT(*) AS n FROM nope GROUP BY g")
    assert handle.status == "failed"
    assert "KeyError" in handle.error


# ---------------------------------------------------------------------------
# Scheduler: signature grouping, compile-once, fairness
# ---------------------------------------------------------------------------

def test_scheduler_identical_queries_compile_once(catalog):
    session = Session(catalog, seed=7)
    sql = ("SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
           "WHERE l_quantity < 24 ERROR 8% CONFIDENCE 95%")
    warm = session.sql(sql)          # first query pays pilot + compilations
    assert warm.status == "done"
    handles = [session.submit(sql) for _ in range(6)]
    assert session.scheduler.pending_count == 6
    done = session.drain()
    stats = session.scheduler.last_drain
    assert [h.query_id for h in done] == [h.query_id for h in handles]
    assert all(h.status == "done" for h in done)
    # Identical queries re-derive identical content seeds, so the whole herd
    # answers from the session result cache: zero pilots, zero compilations,
    # and every member returns the warm query's original guaranteed answer.
    assert stats.compile_misses == 0, stats
    assert stats.pilots_run == 0
    assert stats.result_hits == 6
    assert stats.n_groups == 1 and stats.group_sizes == [6]
    assert all(h.fallback is None for h in done)
    assert all(h.cached for h in done)
    assert all(h.seed == warm.seed for h in done)
    assert all(np.array_equal(h.result().values, warm.result().values)
               for h in done)
    # execution-counting twin (cache off, shared pilot): tests/test_runtime.py


def test_scheduler_submission_fair_grouping(catalog):
    session = Session(catalog, seed=1)
    sql_a = "SELECT SUM(l_quantity) AS qty FROM lineitem ERROR 10% CONFIDENCE 90%"
    sql_b = ("SELECT COUNT(*) AS n FROM lineitem WHERE l_shipdate < 2000 "
             "ERROR 10% CONFIDENCE 90%")
    order = [session.submit(s) for s in (sql_a, sql_b, sql_a, sql_b, sql_a)]
    done = session.drain()
    stats = session.scheduler.last_drain
    assert stats.n_groups == 2 and sorted(stats.group_sizes) == [2, 3]
    # group A arrived first -> all of A runs before any of B,
    # members in submission order within each group
    ids = [h.query_id for h in done]
    assert ids == [order[0].query_id, order[2].query_id, order[4].query_id,
                   order[1].query_id, order[3].query_id]


def test_session_rejects_catalog_and_executor_together(catalog):
    from repro.engine.executor import Executor
    with pytest.raises(ValueError, match="not both"):
        Session(catalog, executor=Executor(catalog))


def test_scheduler_resubmit_is_idempotent(catalog):
    session = Session(catalog, seed=4)
    handle = session.submit("SELECT COUNT(*) AS n FROM lineitem")
    session.scheduler.submit(handle)  # retry must not double-queue
    assert session.scheduler.pending_count == 1
    done = session.drain()
    assert len(done) == 1 and session.scheduler.last_drain.n_queries == 1


def test_scheduler_max_queries_batching(catalog):
    session = Session(catalog, seed=2)
    sql = "SELECT SUM(l_quantity) AS qty FROM lineitem ERROR 10% CONFIDENCE 90%"
    for _ in range(5):
        session.submit(sql)
    first = session.drain(max_queries=2)
    assert len(first) == 2 and session.scheduler.pending_count == 3
    rest = session.drain()
    assert len(rest) == 3 and session.scheduler.pending_count == 0
    with pytest.raises(ValueError):
        session.drain(max_queries=0)
