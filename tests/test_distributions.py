"""stats.distributions: percentile functions and Lemma-B.1 helper bounds."""

import math

import numpy as np
import pytest

from repro.stats import distributions as D


KNOWN_Z = {0.5: 0.0, 0.975: 1.959964, 0.95: 1.644854, 0.995: 2.575829}


def test_normal_ppf_known_values():
    for p, z in KNOWN_Z.items():
        assert D.normal_ppf(p) == pytest.approx(z, abs=1e-4)


def test_normal_ppf_acklam_fallback_accuracy():
    # the hand approximation must agree with scipy (if present) everywhere
    for p in np.linspace(1e-6, 1 - 1e-6, 501):
        assert D._acklam(float(p)) == pytest.approx(D.normal_ppf(float(p)), abs=2e-6)


def test_normal_ppf_rejects_bad_p():
    with pytest.raises(ValueError):
        D._acklam(0.0)
    with pytest.raises(ValueError):
        D._acklam(1.0)


def test_student_t_known_values():
    # classic table values
    assert D.student_t_ppf(0.975, 10) == pytest.approx(2.2281, abs=2e-3)
    assert D.student_t_ppf(0.95, 30) == pytest.approx(1.6973, abs=2e-3)
    assert D.student_t_ppf(0.99, 100) == pytest.approx(2.3642, abs=2e-3)


def test_student_t_fallback_close_to_scipy():
    try:
        from scipy import stats as sps
    except Exception:
        pytest.skip("scipy unavailable")
    import repro.stats.distributions as mod

    for df in (5, 10, 30, 100):
        for p in (0.9, 0.95, 0.975, 0.99):
            z = mod._acklam(p)
            g1 = (z ** 3 + z) / 4.0
            g2 = (5 * z ** 5 + 16 * z ** 3 + 3 * z) / 96.0
            g3 = (3 * z ** 7 + 19 * z ** 5 + 17 * z ** 3 - 15 * z) / 384.0
            g4 = (79 * z ** 9 + 776 * z ** 7 + 1482 * z ** 5 - 1920 * z ** 3 - 945 * z) / 92160.0
            approx = z + g1 / df + g2 / df ** 2 + g3 / df ** 3 + g4 / df ** 4
            exact = float(sps.t.ppf(p, df))
            assert approx == pytest.approx(exact, rel=2e-3)


def test_chi2_known_values():
    assert D.chi2_ppf(0.05, 29) == pytest.approx(17.708, rel=2e-2)
    assert D.chi2_ppf(0.95, 29) == pytest.approx(42.557, rel=2e-2)


def test_degenerate_inputs_raise():
    with pytest.raises(ValueError):
        D.student_t_ppf(0.9, 0)
    with pytest.raises(ValueError):
        D.chi2_ppf(0.9, -1)


def test_binomial_lower_bound_coverage():
    """P[n >= bound] >= 1-delta, checked by Monte Carlo."""
    rng = np.random.default_rng(0)
    N, theta, delta = 5000, 0.02, 0.05
    bound = D.binomial_lower_bound(N, theta, delta)
    draws = rng.binomial(N, theta, size=4000)
    cover = (draws >= bound).mean()
    assert cover >= 1 - delta - 0.02
    assert bound > 0


def test_population_lower_bound_coverage():
    """P[N >= L_N] >= 1-delta when n_p ~ Bin(N, theta_p)."""
    rng = np.random.default_rng(1)
    N, theta_p, delta = 20_000, 0.01, 0.05
    covered = 0
    trials = 2000
    for _ in range(trials):
        n_p = rng.binomial(N, theta_p)
        if n_p == 0:
            continue
        L_N = D.population_lower_bound(n_p, theta_p, delta)
        covered += N >= L_N
    assert covered / trials >= 1 - delta - 0.02


def test_bounds_zero_inputs():
    assert D.binomial_lower_bound(0, 0.5, 0.1) == 0.0
    assert D.population_lower_bound(0, 0.5, 0.1) == 0.0
    assert math.isfinite(D.population_lower_bound(100, 0.01, 0.05))
